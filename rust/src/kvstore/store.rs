//! The sharded block store with lease semantics.
//!
//! Operations (all meter traffic against the requesting worker's machine):
//!
//! * [`KvStore::lease_block`] — move a block out of its shard to a worker.
//!   A block can have **at most one holder**; double-lease is a protocol
//!   violation and errors loudly (this is the §3.2 disjointness guarantee
//!   made mechanical).
//! * [`KvStore::stage_block`] — the same lease, issued *ahead of need* by
//!   the pipelined prefetch engine (`coordinator::pipeline`) while the
//!   current round is still sampling; metered as overlapped
//!   ([`TransferKind::BlockPrefetch`]) traffic.
//! * [`KvStore::commit_block`] — return the (mutated) block.
//! * [`KvStore::read_totals`] / [`KvStore::merge_totals_delta`] — the §3.3
//!   relaxed-consistency channel for `C_k`: snapshot at round start, merge
//!   signed deltas at round end.
//!
//! Lease, stage and commit also come in `*_with_receipt` forms returning a
//! [`LeaseReceipt`] — the flow endpoints and wire bytes in caller-held
//! form, so a concurrent caller (the prefetch engine) can time its flows
//! deterministically without depending on the shared meter's drain order.
//!
//! ## Concurrency
//!
//! The store is **shard-locked**: every method takes `&self`, and state is
//! split into one mutex per shard-home machine plus one for the totals and
//! one for the traffic meter. Leases and commits of blocks homed on
//! different machines therefore never serialize — which is exactly the
//! contention profile of the paper's distributed hash table (§3.2), where
//! each machine serves its own shard independently. The threaded execution
//! engine (`coordinator::parallel`) relies on this, and the pipelined
//! prefetch engine's flusher thread (`coordinator::pipeline`) issues
//! commits and stages through it concurrently with sampling.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};

use anyhow::{bail, Context, Result};

use crate::cluster::Flow;
use crate::model::wire;
use crate::model::{ModelBlock, TopicCounts};

use super::shard::ShardMap;
use super::traffic::{Transfer, TrafficMeter, TransferKind};

/// The endpoints and wire size of one store transfer, returned to the
/// caller that triggered it. Receipts let concurrent callers reconstruct
/// their flows in a deterministic order (the shared [`TrafficMeter`]'s
/// pending list is completion-ordered and therefore racy under the
/// pipelined engine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LeaseReceipt {
    /// Sending machine.
    pub src: usize,
    /// Receiving machine.
    pub dst: usize,
    /// Wire-encoded bytes moved.
    pub bytes: u64,
}

impl LeaseReceipt {
    /// The receipt as a network-model [`Flow`].
    pub fn flow(&self) -> Flow {
        Flow { src: self.src, dst: self.dst, bytes: self.bytes }
    }
}

/// Per-machine shard state: blocks at home, plus the lease ledger for
/// blocks this machine is responsible for.
#[derive(Default)]
struct MachineShard {
    /// Blocks currently resident (not leased), by id.
    resident: BTreeMap<u32, ModelBlock>,
    /// Holder machine of each leased block.
    leased_to: BTreeMap<u32, usize>,
}

/// Sharded in-memory store of model blocks + topic totals.
pub struct KvStore {
    shards: ShardMap,
    /// One lock per shard-home machine (index = machine id).
    slots: Vec<Mutex<MachineShard>>,
    /// Authoritative topic totals (machine hosting it = totals_home).
    totals: Mutex<TopicCounts>,
    totals_home: usize,
    meter: Mutex<TrafficMeter>,
}

impl KvStore {
    /// Build from the initial blocks and totals.
    pub fn new(blocks: Vec<ModelBlock>, totals: TopicCounts, shards: ShardMap) -> KvStore {
        assert_eq!(blocks.len(), shards.num_blocks());
        let machines = (0..shards.num_blocks())
            .map(|b| shards.home(b) + 1)
            .max()
            .unwrap_or(1);
        let mut slots: Vec<Mutex<MachineShard>> = Vec::with_capacity(machines);
        for _ in 0..machines {
            slots.push(Mutex::new(MachineShard::default()));
        }
        for b in blocks {
            let home = shards.home(b.id as usize);
            slots[home].get_mut().unwrap().resident.insert(b.id, b);
        }
        KvStore {
            shards,
            slots,
            totals: Mutex::new(totals),
            totals_home: 0,
            meter: Mutex::new(TrafficMeter::new()),
        }
    }

    fn slot(&self, block: u32) -> MutexGuard<'_, MachineShard> {
        self.slots[self.shards.home(block as usize)]
            .lock()
            .expect("kv shard lock poisoned")
    }

    /// Lease block `id` to a worker on `worker_machine`. Records the fetch
    /// flow `home(id) → worker_machine` sized by the block's wire encoding.
    pub fn lease_block(&self, id: u32, worker_machine: usize) -> Result<ModelBlock> {
        Ok(self.lease_inner(id, worker_machine, TransferKind::BlockFetch)?.0)
    }

    /// [`KvStore::lease_block`] returning the transfer's [`LeaseReceipt`].
    pub fn lease_block_with_receipt(
        &self,
        id: u32,
        worker_machine: usize,
    ) -> Result<(ModelBlock, LeaseReceipt)> {
        self.lease_inner(id, worker_machine, TransferKind::BlockFetch)
    }

    /// Prefetch block `id` into a staging buffer on `worker_machine` ahead
    /// of the round that needs it. Identical lease semantics to
    /// [`KvStore::lease_block`] — at most one holder, same wire bytes —
    /// but metered as [`TransferKind::BlockPrefetch`] because the transfer
    /// runs overlapped with sampling, off the round's critical path.
    pub fn stage_block(
        &self,
        id: u32,
        worker_machine: usize,
    ) -> Result<(ModelBlock, LeaseReceipt)> {
        self.lease_inner(id, worker_machine, TransferKind::BlockPrefetch)
    }

    fn lease_inner(
        &self,
        id: u32,
        worker_machine: usize,
        kind: TransferKind,
    ) -> Result<(ModelBlock, LeaseReceipt)> {
        let block = {
            let mut slot = self.slot(id);
            if let Some(&holder) = slot.leased_to.get(&id) {
                bail!("protocol violation: block {id} already leased to machine {holder}");
            }
            let block = slot
                .resident
                .remove(&id)
                .with_context(|| format!("block {id} not in store"))?;
            slot.leased_to.insert(id, worker_machine);
            block
        };
        let receipt = LeaseReceipt {
            src: self.shards.home(id as usize),
            dst: worker_machine,
            bytes: wire::encode_block(&block).len() as u64,
        };
        self.meter.lock().expect("kv meter lock poisoned").record(
            receipt.src,
            receipt.dst,
            receipt.bytes,
            kind,
        );
        Ok((block, receipt))
    }

    /// Commit a leased block back. Records the commit flow.
    pub fn commit_block(&self, block: ModelBlock, worker_machine: usize) -> Result<()> {
        self.commit_block_with_receipt(block, worker_machine).map(|_| ())
    }

    /// [`KvStore::commit_block`] returning the transfer's [`LeaseReceipt`].
    ///
    /// Committing **invalidates the block's alias-table cache**: the rows
    /// just changed, so the next lease (including the pipelined engine's
    /// immediate re-lease into staging) must rebuild its proposal tables
    /// from fresh counts.
    pub fn commit_block_with_receipt(
        &self,
        mut block: ModelBlock,
        worker_machine: usize,
    ) -> Result<LeaseReceipt> {
        block.alias.clear();
        let id = block.id;
        let bytes = wire::encode_block(&block).len() as u64;
        {
            let mut slot = self.slot(id);
            match slot.leased_to.remove(&id) {
                None => bail!("protocol violation: commit of unleased block {id}"),
                Some(holder) if holder != worker_machine => {
                    // Restore the ledger before erroring so the store stays
                    // inspectable.
                    slot.leased_to.insert(id, holder);
                    bail!(
                        "protocol violation: block {id} leased to machine {holder}, committed from {worker_machine}"
                    );
                }
                Some(_) => {}
            }
            slot.resident.insert(id, block);
        }
        let receipt = LeaseReceipt {
            src: worker_machine,
            dst: self.shards.home(id as usize),
            bytes,
        };
        self.meter.lock().expect("kv meter lock poisoned").record(
            receipt.src,
            receipt.dst,
            receipt.bytes,
            TransferKind::BlockCommit,
        );
        Ok(receipt)
    }

    /// Read-only serving lease: copy block `id`'s rows to `reader_machine`
    /// **without taking ownership** — the block stays resident, so any
    /// number of concurrent readers proceed in parallel (shard-locked only
    /// for the duration of the copy), which is what lets the serving tier
    /// (`serve::ShardedTopicModel`) page blocks while other queries are in
    /// flight. Metered as [`TransferKind::BlockRead`] so serving traffic
    /// stays separable from training traffic. Errors if the block is
    /// exclusively leased out (the store is mid-training, not quiescent).
    pub fn read_block(&self, id: u32, reader_machine: usize) -> Result<ModelBlock> {
        let block = {
            let slot = self.slot(id);
            if let Some(&holder) = slot.leased_to.get(&id) {
                bail!(
                    "block {id} is exclusively leased to machine {holder} — the store is \
                     mid-training; serve from a quiescent store"
                );
            }
            slot.resident
                .get(&id)
                .with_context(|| format!("block {id} not in store"))?
                .clone()
        };
        // Length-only metering: a starved serving cache reads blocks per
        // token, so the O(block) encode allocation stays off this path.
        self.meter.lock().expect("kv meter lock poisoned").record(
            self.shards.home(id as usize),
            reader_machine,
            wire::encoded_block_len(&block),
            TransferKind::BlockRead,
        );
        Ok(block)
    }

    /// Heap bytes of a resident (non-leased) block, or `None` if the block
    /// is currently leased out (or unknown). The pipelined engine uses this
    /// for staging-budget checks *before* paying for a prefetch.
    pub fn resident_block_bytes(&self, id: u32) -> Option<u64> {
        self.slot(id).resident.get(&id).map(|b| b.bytes())
    }

    /// Snapshot the topic totals (round-start sync of §3.3).
    pub fn read_totals(&self, worker_machine: usize) -> TopicCounts {
        let snapshot = self.totals.lock().expect("kv totals lock poisoned").clone();
        let bytes = wire::encode_totals(&snapshot).len() as u64;
        self.meter.lock().expect("kv meter lock poisoned").record(
            self.totals_home,
            worker_machine,
            bytes,
            TransferKind::TotalsRead,
        );
        snapshot
    }

    /// Merge a worker's signed `C_k` delta (round-end).
    pub fn merge_totals_delta(&self, delta: &TopicCounts, worker_machine: usize) {
        let bytes = wire::encode_totals(delta).len() as u64;
        {
            let mut meter = self.meter.lock().expect("kv meter lock poisoned");
            meter.record(worker_machine, self.totals_home, bytes, TransferKind::PsSync);
            // Classified as TotalsMerge for reporting:
            meter.record(worker_machine, self.totals_home, 0, TransferKind::TotalsMerge);
        }
        self.totals.lock().expect("kv totals lock poisoned").merge(delta);
    }

    /// Clone of the authoritative totals (truth `T` of the Fig 3 metric).
    pub fn totals_snapshot(&self) -> TopicCounts {
        self.totals.lock().expect("kv totals lock poisoned").clone()
    }

    /// Number of blocks currently leased out.
    pub fn num_leased(&self) -> usize {
        self.slots
            .iter()
            .map(|s| s.lock().expect("kv shard lock poisoned").leased_to.len())
            .sum()
    }

    /// Total bytes moved so far (all transfer kinds).
    pub fn total_bytes(&self) -> u64 {
        self.meter.lock().expect("kv meter lock poisoned").total_bytes()
    }

    /// Bytes moved so far for one transfer kind.
    pub fn bytes_of(&self, kind: TransferKind) -> u64 {
        self.meter.lock().expect("kv meter lock poisoned").bytes_of(kind)
    }

    /// Bytes moved overlapped with compute (prefetch traffic) — see
    /// [`super::traffic::TrafficMeter::overlapped_bytes`].
    pub fn overlapped_bytes(&self) -> u64 {
        self.meter.lock().expect("kv meter lock poisoned").overlapped_bytes()
    }

    /// Take the pending transfers (for a phase's network timing) as flows.
    pub fn drain_flows(&self) -> Vec<Flow> {
        self.meter.lock().expect("kv meter lock poisoned").drain_flows()
    }

    /// Snapshot of the pending (un-drained) transfers.
    pub fn pending_transfers(&self) -> Vec<Transfer> {
        self.meter.lock().expect("kv meter lock poisoned").pending().to_vec()
    }

    /// Visit every resident (non-leased) block — the quiescent model view
    /// used by the driver's log-likelihood pass. The visitor runs with all
    /// shard locks held; iteration order is (home machine, block id).
    pub fn with_resident_blocks<R>(
        &self,
        f: impl FnOnce(&mut dyn Iterator<Item = &ModelBlock>) -> R,
    ) -> R {
        let guards: Vec<MutexGuard<'_, MachineShard>> = self
            .slots
            .iter()
            .map(|s| s.lock().expect("kv shard lock poisoned"))
            .collect();
        let mut it = guards.iter().flat_map(|g| g.resident.values());
        f(&mut it)
    }

    /// Bytes of shard storage on each machine (memory accounting).
    pub fn shard_bytes(&self, machines: usize) -> Vec<u64> {
        let mut per = vec![0u64; machines];
        for (home, slot) in self.slots.iter().enumerate() {
            let slot = slot.lock().expect("kv shard lock poisoned");
            let bytes: u64 = slot.resident.values().map(|b| b.bytes()).sum();
            per[home] += bytes;
        }
        per
    }

    /// Validate internal consistency: every block either resident or
    /// leased; totals match the column sums of resident blocks only if
    /// nothing is leased.
    pub fn check_quiescent_consistency(&self, num_topics: usize) -> Result<()> {
        let leased = self.num_leased();
        if leased != 0 {
            bail!("store not quiescent: {leased} blocks leased");
        }
        let mut sums = vec![0i64; num_topics];
        self.with_resident_blocks(|blocks| {
            for b in blocks {
                for (k, s) in b.column_sums(num_topics).into_iter().enumerate() {
                    sums[k] += s;
                }
            }
        });
        let totals = self.totals_snapshot();
        if sums != totals.as_slice() {
            bail!(
                "totals out of sync with blocks: blocks={sums:?} totals={:?}",
                totals.as_slice()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::Config;
    use crate::util::rng::Pcg64;

    fn setup(num_blocks: usize, machines: usize) -> KvStore {
        let cfg = Config::from_str(&format!(
            "[cluster]\npreset = \"custom\"\nmachines = {machines}"
        ))
        .unwrap();
        let spec = ClusterSpec::from_config(&cfg.cluster);
        let mut rng = Pcg64::new(1);
        let k = 8;
        let mut totals = TopicCounts::zeros(k);
        let blocks: Vec<ModelBlock> = (0..num_blocks as u32)
            .map(|id| {
                let mut b = ModelBlock::empty(id, id * 10, (id + 1) * 10);
                for w in b.lo..b.hi {
                    for _ in 0..rng.next_below(5) {
                        let t = rng.next_below(k as u64) as u32;
                        b.row_mut(w).inc(t);
                        totals.inc(t as usize);
                    }
                }
                b
            })
            .collect();
        let shards = ShardMap::round_robin(num_blocks, &spec);
        KvStore::new(blocks, totals, shards)
    }

    #[test]
    fn lease_commit_cycle() {
        let kv = setup(4, 2);
        let b = kv.lease_block(2, 1).unwrap();
        assert_eq!(kv.num_leased(), 1);
        kv.commit_block(b, 1).unwrap();
        assert_eq!(kv.num_leased(), 0);
        kv.check_quiescent_consistency(8).unwrap();
        assert!(kv.total_bytes() > 0);
    }

    #[test]
    fn stage_is_a_lease_metered_as_overlapped() {
        let kv = setup(4, 2);
        let fetch_before = kv.bytes_of(TransferKind::BlockFetch);
        let (b, receipt) = kv.stage_block(2, 1).unwrap();
        // Same lease ledger as a normal fetch: the block has one holder.
        assert_eq!(kv.num_leased(), 1);
        let err = kv.lease_block(2, 0).unwrap_err().to_string();
        assert!(err.contains("already leased"), "{err}");
        // Metered as prefetch, not fetch; receipt matches the meter.
        assert_eq!(kv.bytes_of(TransferKind::BlockFetch), fetch_before);
        assert_eq!(kv.bytes_of(TransferKind::BlockPrefetch), receipt.bytes);
        assert_eq!(kv.overlapped_bytes(), receipt.bytes);
        assert_eq!(receipt.dst, 1);
        assert!(receipt.bytes > 0);
        kv.commit_block(b, 1).unwrap();
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn receipts_mirror_recorded_flows() {
        let kv = setup(4, 2);
        let (b, lease) = kv.lease_block_with_receipt(1, 0).unwrap();
        let commit = kv.commit_block_with_receipt(b, 0).unwrap();
        // Commit is the reverse direction of the lease, same payload shape.
        assert_eq!(lease.src, commit.dst);
        assert_eq!(lease.dst, commit.src);
        assert!(lease.bytes > 0 && commit.bytes > 0);
        let flows = kv.drain_flows();
        assert!(flows.contains(&lease.flow()));
        assert!(flows.contains(&commit.flow()));
    }

    #[test]
    fn resident_block_bytes_tracks_leases() {
        let kv = setup(3, 2);
        let before = kv.resident_block_bytes(0).unwrap();
        assert!(before > 0);
        let b = kv.lease_block(0, 0).unwrap();
        assert_eq!(kv.resident_block_bytes(0), None);
        kv.commit_block(b, 0).unwrap();
        assert_eq!(kv.resident_block_bytes(0), Some(before));
    }

    #[test]
    fn commit_invalidates_alias_cache() {
        // Proposal tables are lease-scoped: whatever the holder cached on
        // the block must be gone by the next lease (the rows changed), so
        // staged/prefetched blocks always carry fresh tables.
        let kv = setup(2, 2);
        let mut b = kv.lease_block(0, 0).unwrap();
        b.alias.ensure(b.rows.len(), 0).build(0, &b.rows[0], &mut Vec::new());
        assert!(b.alias_bytes() > 0);
        kv.commit_block(b, 0).unwrap();
        let b2 = kv.lease_block(0, 0).unwrap();
        assert_eq!(b2.alias_bytes(), 0, "commit must clear the alias cache");
        kv.commit_block(b2, 0).unwrap();
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn read_block_is_a_concurrent_copy() {
        let kv = setup(4, 2);
        let before = kv.bytes_of(TransferKind::BlockRead);
        // Two "concurrent" readers: both get full copies, nothing leases.
        let a = kv.read_block(2, 0).unwrap();
        let b = kv.read_block(2, 1).unwrap();
        assert_eq!(a, b);
        assert_eq!(kv.num_leased(), 0);
        assert!(kv.bytes_of(TransferKind::BlockRead) > before);
        // The original is untouched: an exclusive lease still works …
        let owned = kv.lease_block(2, 0).unwrap();
        assert_eq!(owned, a);
        // … and while it is out, serving reads fail loudly.
        let err = kv.read_block(2, 1).unwrap_err().to_string();
        assert!(err.contains("exclusively leased"), "{err}");
        kv.commit_block(owned, 0).unwrap();
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn read_block_copies_do_not_alias_store_state() {
        // Mutating a serving copy must never reach the store.
        let kv = setup(2, 2);
        let mut copy = kv.read_block(0, 0).unwrap();
        copy.row_mut(copy.lo).inc(7);
        drop(copy);
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn commit_clears_alias_on_every_return_path() {
        // Direct coverage of the commit-time alias invalidation contract
        // (previously only exercised indirectly through pipeline
        // determinism): whatever the holder cached must be gone after
        // `commit_block`, `commit_block_with_receipt`, and the staged
        // re-lease the pipelined engine performs.
        let kv = setup(2, 2);

        // Plain commit.
        let mut b = kv.lease_block(0, 0).unwrap();
        b.alias.ensure(b.rows.len(), 0).build(0, &b.rows[0], &mut Vec::new());
        assert!(b.alias_bytes() > 0);
        kv.commit_block(b, 0).unwrap();
        let fresh = kv.lease_block(0, 0).unwrap();
        assert_eq!(fresh.alias_bytes(), 0, "plain commit must clear the alias cache");
        kv.commit_block(fresh, 0).unwrap();

        // Receipt-returning commit (the pipelined flusher's path).
        let mut b = kv.lease_block(0, 1).unwrap();
        b.alias.ensure(b.rows.len(), 0).build(0, &b.rows[0], &mut Vec::new());
        kv.commit_block_with_receipt(b, 1).unwrap();
        let staged = kv.stage_block(0, 0).unwrap().0;
        assert_eq!(staged.alias_bytes(), 0, "staged re-lease must carry a fresh alias slot");
        kv.commit_block(staged, 0).unwrap();

        // Serving reads after a commit see no stale alias either.
        let read = kv.read_block(0, 0).unwrap();
        assert_eq!(read.alias_bytes(), 0);
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn double_lease_rejected() {
        let kv = setup(4, 2);
        let _b = kv.lease_block(0, 0).unwrap();
        let err = kv.lease_block(0, 1).unwrap_err().to_string();
        assert!(err.contains("already leased"), "{err}");
    }

    #[test]
    fn commit_from_wrong_machine_rejected() {
        let kv = setup(4, 2);
        let b = kv.lease_block(0, 0).unwrap();
        assert!(kv.commit_block(b, 1).is_err());
        // Ledger intact: the lease is still attributed to machine 0.
        assert_eq!(kv.num_leased(), 1);
    }

    #[test]
    fn commit_unleased_rejected() {
        let kv = setup(4, 2);
        let b = ModelBlock::empty(0, 0, 10);
        assert!(kv.commit_block(b, 0).is_err());
    }

    #[test]
    fn totals_round_trip() {
        let kv = setup(2, 2);
        let snap = kv.read_totals(1);
        let mut delta = TopicCounts::zeros(8);
        delta.inc(3);
        delta.dec(0);
        kv.merge_totals_delta(&delta, 1);
        let now = kv.totals_snapshot();
        assert_eq!(now.get(3), snap.get(3) + 1);
        assert_eq!(now.get(0), snap.get(0) - 1);
    }

    #[test]
    fn quiescent_check_detects_leak() {
        let kv = setup(2, 2);
        let _b = kv.lease_block(0, 0).unwrap();
        assert!(kv.check_quiescent_consistency(8).is_err());
    }

    #[test]
    fn mutated_commit_breaks_totals_until_delta_merged() {
        // Committing a mutated block without merging the C_k delta leaves
        // the store inconsistent — the §3.3 channel is what fixes it.
        let kv = setup(2, 2);
        let mut b = kv.lease_block(0, 0).unwrap();
        b.row_mut(b.lo).inc(5);
        kv.commit_block(b, 0).unwrap();
        assert!(kv.check_quiescent_consistency(8).is_err());
        let mut delta = TopicCounts::zeros(8);
        delta.inc(5);
        kv.merge_totals_delta(&delta, 0);
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn concurrent_round_from_shared_reference() {
        // The shard-locked store supports a whole round — totals read,
        // lease, commit, delta merge — driven from plain `&KvStore` on
        // many threads at once, one block per "worker".
        let blocks = 8;
        let kv = setup(blocks, 4);
        let before = kv.totals_snapshot();
        std::thread::scope(|s| {
            for w in 0..blocks as u32 {
                let kv = &kv;
                s.spawn(move || {
                    let machine = (w as usize) % 4;
                    let _snap = kv.read_totals(machine);
                    let mut b = kv.lease_block(w, machine).unwrap();
                    b.row_mut(b.lo).inc((w % 8) as u32);
                    kv.commit_block(b, machine).unwrap();
                    let mut delta = TopicCounts::zeros(8);
                    delta.inc((w % 8) as usize);
                    kv.merge_totals_delta(&delta, machine);
                });
            }
        });
        assert_eq!(kv.num_leased(), 0);
        kv.check_quiescent_consistency(8).unwrap();
        let after = kv.totals_snapshot();
        let sum = |t: &TopicCounts| t.as_slice().iter().sum::<i64>();
        assert_eq!(sum(&after), sum(&before) + blocks as i64);
    }

    #[test]
    fn with_resident_blocks_visits_everything_once() {
        let kv = setup(6, 3);
        let ids = kv.with_resident_blocks(|blocks| {
            let mut ids: Vec<u32> = blocks.map(|b| b.id).collect();
            ids.sort_unstable();
            ids
        });
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }
}
