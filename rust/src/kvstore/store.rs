//! The sharded block store with lease semantics.
//!
//! Operations (all meter traffic against the requesting worker's machine):
//!
//! * [`KvStore::lease_block`] — move a block out of its shard to a worker.
//!   A block can have **at most one holder**; double-lease is a protocol
//!   violation and errors loudly (this is the §3.2 disjointness guarantee
//!   made mechanical).
//! * [`KvStore::stage_block`] — the same lease, issued *ahead of need* by
//!   the pipelined prefetch engine (`coordinator::pipeline`) while the
//!   current round is still sampling; metered as overlapped
//!   ([`TransferKind::BlockPrefetch`]) traffic.
//! * [`KvStore::commit_block`] — return the (mutated) block.
//! * [`KvStore::read_totals`] / [`KvStore::merge_totals_delta`] — the §3.3
//!   relaxed-consistency channel for `C_k`: snapshot at round start, merge
//!   signed deltas at round end.
//!
//! Lease, stage and commit also come in `*_with_receipt` forms returning a
//! [`LeaseReceipt`] — the flow endpoints and wire bytes in caller-held
//! form, so a concurrent caller (the prefetch engine) can time its flows
//! deterministically without depending on the shared meter's drain order.
//!
//! ## Concurrency
//!
//! The store is **shard-locked**: every method takes `&self`, and state is
//! split into one mutex per shard-home machine plus one for the totals and
//! one for the traffic meter. Leases and commits of blocks homed on
//! different machines therefore never serialize — which is exactly the
//! contention profile of the paper's distributed hash table (§3.2), where
//! each machine serves its own shard independently. The threaded execution
//! engine (`coordinator::parallel`) relies on this, and the pipelined
//! prefetch engine's flusher thread (`coordinator::pipeline`) issues
//! commits and stages through it concurrently with sampling.
//!
//! ## Fault tolerance
//!
//! When recovery is enabled ([`KvStore::enable_recovery`]) every lease
//! keeps a **recovery copy** of the block at its shard-home, and the
//! store's round clock ([`KvStore::advance_round`]) stamps each lease.
//! A lease that survives *more than* `timeout_rounds` round boundaries
//! without a commit is reported by [`KvStore::expired_leases`] and can be
//! rolled back with [`KvStore::revoke_lease`] — the recovery copy becomes
//! resident again, sacrificing only the dead holder's uncommitted round.
//! Staged prefetch leases ([`KvStore::stage_block`]) age under the same
//! clock: a healthy staged lease is committed one boundary after it was
//! taken, so it never trips a `timeout_rounds >= 1` deadline, while a
//! staged block stranded by its consumer's death expires like any other
//! lease. [`KvStore::fail_home`] simulates losing a machine's shard-home
//! by promoting its replica on a backup machine (blocks survive; only
//! placement and flow endpoints move), and
//! [`KvStore::inject_read_fault`] arms paging faults for the serving
//! tier's error-isolation tests.
//!
//! ## Out-of-core tier
//!
//! With storage attached ([`KvStore::attach_storage`], driven by the
//! `[storage]` config section) each shard-home also owns a log-structured
//! disk segment ([`crate::storage::HomeSegment`]). Any commit (or the
//! attach itself) that leaves a home's resident bytes above the budget
//! **spills** the coldest blocks — victim = minimum (last-commit round,
//! block id), a pure function of store history, never hash order — and a
//! lease or read of a spilled block **recalls** it transparently.
//! Spill/recall traffic is metered as
//! [`TransferKind::BlockSpill`]/[`TransferKind::BlockRecall`] but never
//! becomes a network flow, and the codecs are lossless, so a starved run
//! stays bitwise-equal to a fully resident one (DESIGN.md §Storage).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use anyhow::{bail, Context, Result};

use crate::cluster::Flow;
use crate::error::MpldaError;
use crate::model::wire;
use crate::model::{ModelBlock, TopicCounts};
use crate::storage::{codec, HomeSegment, StorageOptions};

use super::shard::ShardMap;
use super::traffic::{Transfer, TrafficMeter, TransferKind};

/// The endpoints and wire size of one store transfer, returned to the
/// caller that triggered it. Receipts let concurrent callers reconstruct
/// their flows in a deterministic order (the shared [`TrafficMeter`]'s
/// pending list is completion-ordered and therefore racy under the
/// pipelined engine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LeaseReceipt {
    /// Sending machine.
    pub src: usize,
    /// Receiving machine.
    pub dst: usize,
    /// Wire-encoded bytes moved.
    pub bytes: u64,
}

impl LeaseReceipt {
    /// The receipt as a network-model [`Flow`].
    pub fn flow(&self) -> Flow {
        Flow { src: self.src, dst: self.dst, bytes: self.bytes }
    }
}

/// Per-machine shard state: blocks at home, plus the lease ledger for
/// blocks this machine is responsible for.
#[derive(Default)]
struct MachineShard {
    /// Blocks currently resident (not leased), by id.
    resident: BTreeMap<u32, ModelBlock>,
    /// Holder machine of each leased block.
    leased_to: BTreeMap<u32, usize>,
    /// Round-clock value at which each outstanding lease was taken.
    leased_at: BTreeMap<u32, u64>,
    /// Pre-lease copies of leased blocks, kept only when recovery is
    /// enabled; restored by [`KvStore::revoke_lease`].
    recovery: BTreeMap<u32, ModelBlock>,
    /// Disk segment for this home when the out-of-core tier is attached.
    disk: Option<HomeSegment>,
    /// Round-clock stamp of each resident block's last commit. Spill
    /// victim = minimum (stamp, id); a BTreeMap so scans are id-ordered
    /// and the choice is deterministic.
    last_commit: BTreeMap<u32, u64>,
    /// Content heap-bytes of each spilled block, recorded at spill time —
    /// budget queries ([`KvStore::resident_block_bytes`]) answer for
    /// spilled blocks without decoding them.
    spilled_bytes: BTreeMap<u32, u64>,
}

/// Sharded in-memory store of model blocks + topic totals.
pub struct KvStore {
    shards: ShardMap,
    /// One lock per shard-home machine (index = machine id).
    slots: Vec<Mutex<MachineShard>>,
    /// Authoritative topic totals (machine hosting it = totals_home).
    totals: Mutex<TopicCounts>,
    totals_home: usize,
    meter: Mutex<TrafficMeter>,
    /// When true, leases keep a recovery copy at the shard-home so an
    /// expired lease can be revoked instead of losing the block.
    recovery_enabled: bool,
    /// Monotone round counter (advanced by the driver at round ends);
    /// lease ages are measured against it.
    clock: AtomicU64,
    /// Armed paging faults: block id → remaining reads that must fail.
    read_faults: Mutex<BTreeMap<u32, usize>>,
    /// Shard-home relocations from [`KvStore::fail_home`]: block id →
    /// promoted backup machine, consulted before the static [`ShardMap`].
    home_overrides: Mutex<BTreeMap<u32, usize>>,
    /// Out-of-core tier configuration; `None` = fully resident.
    storage: Option<StorageOptions>,
    /// Every spill in order — the eviction-determinism witness
    /// ([`KvStore::spill_sequence`]).
    spill_log: Mutex<Vec<u32>>,
}

impl KvStore {
    /// Build from the initial blocks and totals.
    pub fn new(blocks: Vec<ModelBlock>, totals: TopicCounts, shards: ShardMap) -> KvStore {
        assert_eq!(blocks.len(), shards.num_blocks());
        let machines = (0..shards.num_blocks())
            .map(|b| shards.home(b) + 1)
            .max()
            .unwrap_or(1);
        let mut slots: Vec<Mutex<MachineShard>> = Vec::with_capacity(machines);
        for _ in 0..machines {
            slots.push(Mutex::new(MachineShard::default()));
        }
        for b in blocks {
            let home = shards.home(b.id as usize);
            slots[home].get_mut().unwrap().resident.insert(b.id, b);
        }
        KvStore {
            shards,
            slots,
            totals: Mutex::new(totals),
            totals_home: 0,
            meter: Mutex::new(TrafficMeter::new()),
            recovery_enabled: false,
            clock: AtomicU64::new(0),
            read_faults: Mutex::new(BTreeMap::new()),
            home_overrides: Mutex::new(BTreeMap::new()),
            storage: None,
            spill_log: Mutex::new(Vec::new()),
        }
    }

    /// Keep a recovery copy of every leased block at its shard-home so
    /// that [`KvStore::revoke_lease`] can roll an expired lease back.
    /// Costs one block clone per lease; the driver enables it only when
    /// `coord.lease_timeout_rounds > 0`. Must be called before the store
    /// is shared (hence `&mut self`).
    pub fn enable_recovery(&mut self) {
        self.recovery_enabled = true;
    }

    /// Attach the out-of-core disk tier: every shard-home gets a fresh
    /// log-structured segment file `home-<m>.seg` under `opts.dir`, and
    /// from now on any commit (or this attach itself) that leaves a
    /// home's resident bytes above `opts.budget_bytes` spills the coldest
    /// blocks to disk; leases and reads of spilled blocks recall them
    /// transparently. Must be called before the store is shared (hence
    /// `&mut self`). Each concurrent store needs its own directory.
    pub fn attach_storage(&mut self, opts: StorageOptions) -> Result<()> {
        if opts.budget_bytes == 0 {
            bail!("storage budget must be > 0 bytes (leave storage unattached for fully resident)");
        }
        std::fs::create_dir_all(&opts.dir)
            .with_context(|| format!("creating storage dir {}", opts.dir.display()))?;
        self.storage = Some(opts);
        for home in 0..self.slots.len() {
            let path = self
                .storage
                .as_ref()
                .expect("storage options just attached")
                .dir
                .join(format!("home-{home}.seg"));
            let mut slot = self.slots[home].lock().expect("kv shard lock poisoned");
            slot.disk = Some(HomeSegment::create(&path)?);
            self.enforce_budget(&mut slot, home)?;
        }
        Ok(())
    }

    /// Is the out-of-core tier attached?
    pub fn storage_attached(&self) -> bool {
        self.storage.is_some()
    }

    /// Spill the coldest resident blocks of `home` until its resident
    /// bytes fit the attached budget (no-op when storage is off). The
    /// victim is the resident block minimizing (last-commit round, id) —
    /// computed by scanning id-ordered BTreeMaps, never hash iteration
    /// order — so identical runs produce identical spill sequences
    /// ([`KvStore::spill_sequence`]). A single block larger than the
    /// whole budget spills immediately, leaving the home empty but legal.
    fn enforce_budget(&self, slot: &mut MachineShard, home: usize) -> Result<()> {
        let Some(opts) = &self.storage else { return Ok(()) };
        loop {
            let resident: u64 = slot.resident.values().map(|b| b.bytes()).sum();
            if resident <= opts.budget_bytes || slot.resident.is_empty() {
                return Ok(());
            }
            let victim = slot
                .resident
                .keys()
                .map(|&id| (slot.last_commit.get(&id).copied().unwrap_or(0), id))
                .min()
                .expect("non-empty resident set")
                .1;
            let block = slot.resident.remove(&victim).expect("victim is resident");
            slot.last_commit.remove(&victim);
            let payload = codec::encode_block(&block, opts.encoding);
            slot.disk
                .as_mut()
                .expect("storage attached without a segment")
                .append(victim, opts.encoding, &payload)
                .with_context(|| format!("spilling block {victim} at home {home}"))?;
            slot.spilled_bytes.insert(victim, block.bytes());
            self.meter.lock().expect("kv meter lock poisoned").record(
                home,
                home,
                payload.len() as u64,
                TransferKind::BlockSpill,
            );
            self.spill_log.lock().expect("kv spill log poisoned").push(victim);
        }
    }

    /// Decode a spilled block for a read-only copy **without promoting
    /// it**: what is resident vs spilled must stay a pure function of the
    /// training history, not of serving traffic. `Ok(None)` if `id` is
    /// not spilled at this home.
    fn peek_spilled(
        &self,
        slot: &mut MachineShard,
        home: usize,
        id: u32,
    ) -> Result<Option<ModelBlock>> {
        let Some(disk) = slot.disk.as_mut() else { return Ok(None) };
        let Some((encoding, payload)) = disk.read(id)? else { return Ok(None) };
        let block = codec::decode_block(&payload, encoding)
            .with_context(|| format!("decoding spilled block {id}"))?;
        self.meter.lock().expect("kv meter lock poisoned").record(
            home,
            home,
            payload.len() as u64,
            TransferKind::BlockRecall,
        );
        Ok(Some(block))
    }

    /// Recall a spilled block into the caller's hands, dropping the disk
    /// record (the caller is about to own and mutate the block, so the
    /// on-disk copy would be stale).
    fn recall(&self, slot: &mut MachineShard, home: usize, id: u32) -> Result<Option<ModelBlock>> {
        let Some(block) = self.peek_spilled(slot, home, id)? else {
            return Ok(None);
        };
        if let Some(disk) = slot.disk.as_mut() {
            disk.remove(id)?;
        }
        slot.spilled_bytes.remove(&id);
        Ok(Some(block))
    }

    /// The effective home machine of `block`: a [`KvStore::fail_home`]
    /// promotion if one happened, the static shard map otherwise.
    fn home_of(&self, block: u32) -> usize {
        let overrides = self.home_overrides.lock().expect("kv overrides lock poisoned");
        overrides
            .get(&block)
            .copied()
            .unwrap_or_else(|| self.shards.home(block as usize))
    }

    fn slot(&self, block: u32) -> MutexGuard<'_, MachineShard> {
        self.slots[self.home_of(block)].lock().expect("kv shard lock poisoned")
    }

    /// Lease block `id` to a worker on `worker_machine`. Records the fetch
    /// flow `home(id) → worker_machine` sized by the block's wire encoding.
    pub fn lease_block(&self, id: u32, worker_machine: usize) -> Result<ModelBlock> {
        Ok(self.lease_inner(id, worker_machine, TransferKind::BlockFetch)?.0)
    }

    /// [`KvStore::lease_block`] returning the transfer's [`LeaseReceipt`].
    pub fn lease_block_with_receipt(
        &self,
        id: u32,
        worker_machine: usize,
    ) -> Result<(ModelBlock, LeaseReceipt)> {
        self.lease_inner(id, worker_machine, TransferKind::BlockFetch)
    }

    /// Prefetch block `id` into a staging buffer on `worker_machine` ahead
    /// of the round that needs it. Identical lease semantics to
    /// [`KvStore::lease_block`] — at most one holder, same wire bytes —
    /// but metered as [`TransferKind::BlockPrefetch`] because the transfer
    /// runs overlapped with sampling, off the round's critical path.
    pub fn stage_block(
        &self,
        id: u32,
        worker_machine: usize,
    ) -> Result<(ModelBlock, LeaseReceipt)> {
        self.lease_inner(id, worker_machine, TransferKind::BlockPrefetch)
    }

    fn lease_inner(
        &self,
        id: u32,
        worker_machine: usize,
        kind: TransferKind,
    ) -> Result<(ModelBlock, LeaseReceipt)> {
        let home = self.home_of(id);
        let block = {
            let mut slot = self.slots[home].lock().expect("kv shard lock poisoned");
            if let Some(&holder) = slot.leased_to.get(&id) {
                bail!("protocol violation: block {id} already leased to machine {holder}");
            }
            let block = match slot.resident.remove(&id) {
                Some(b) => Some(b),
                None => self.recall(&mut slot, home, id)?,
            }
            .with_context(|| format!("block {id} not in store"))?;
            slot.last_commit.remove(&id);
            slot.leased_to.insert(id, worker_machine);
            slot.leased_at.insert(id, self.clock.load(Ordering::Relaxed));
            if self.recovery_enabled {
                slot.recovery.insert(id, block.clone());
            }
            block
        };
        let receipt = LeaseReceipt {
            src: home,
            dst: worker_machine,
            bytes: wire::encode_block(&block).len() as u64,
        };
        self.meter.lock().expect("kv meter lock poisoned").record(
            receipt.src,
            receipt.dst,
            receipt.bytes,
            kind,
        );
        Ok((block, receipt))
    }

    /// Commit a leased block back. Records the commit flow.
    pub fn commit_block(&self, block: ModelBlock, worker_machine: usize) -> Result<()> {
        self.commit_block_with_receipt(block, worker_machine).map(|_| ())
    }

    /// [`KvStore::commit_block`] returning the transfer's [`LeaseReceipt`].
    ///
    /// Committing **invalidates the block's alias-table cache**: the rows
    /// just changed, so the next lease (including the pipelined engine's
    /// immediate re-lease into staging) must rebuild its proposal tables
    /// from fresh counts.
    pub fn commit_block_with_receipt(
        &self,
        mut block: ModelBlock,
        worker_machine: usize,
    ) -> Result<LeaseReceipt> {
        block.alias.clear();
        let id = block.id;
        let bytes = wire::encode_block(&block).len() as u64;
        let home = self.home_of(id);
        {
            let mut slot = self.slots[home].lock().expect("kv shard lock poisoned");
            match slot.leased_to.remove(&id) {
                None => bail!("protocol violation: commit of unleased block {id}"),
                Some(holder) if holder != worker_machine => {
                    // Restore the ledger before erroring so the store stays
                    // inspectable.
                    slot.leased_to.insert(id, holder);
                    bail!(
                        "protocol violation: block {id} leased to machine {holder}, committed from {worker_machine}"
                    );
                }
                Some(_) => {}
            }
            slot.leased_at.remove(&id);
            slot.recovery.remove(&id);
            slot.resident.insert(id, block);
            slot.last_commit.insert(id, self.clock.load(Ordering::Relaxed));
            self.enforce_budget(&mut slot, home)?;
        }
        let receipt = LeaseReceipt {
            src: worker_machine,
            dst: home,
            bytes,
        };
        self.meter.lock().expect("kv meter lock poisoned").record(
            receipt.src,
            receipt.dst,
            receipt.bytes,
            TransferKind::BlockCommit,
        );
        Ok(receipt)
    }

    /// Read-only serving lease: copy block `id`'s rows to `reader_machine`
    /// **without taking ownership** — the block stays resident, so any
    /// number of concurrent readers proceed in parallel (shard-locked only
    /// for the duration of the copy), which is what lets the serving tier
    /// (`serve::ShardedTopicModel`) page blocks while other queries are in
    /// flight. Metered as [`TransferKind::BlockRead`] so serving traffic
    /// stays separable from training traffic. Errors if the block is
    /// exclusively leased out (the store is mid-training, not quiescent).
    pub fn read_block(&self, id: u32, reader_machine: usize) -> Result<ModelBlock> {
        {
            let mut faults = self.read_faults.lock().expect("kv faults lock poisoned");
            if let Some(remaining) = faults.get_mut(&id) {
                *remaining -= 1;
                if *remaining == 0 {
                    faults.remove(&id);
                }
                return Err(MpldaError::ReadFault { block: id }.into());
            }
        }
        let home = self.home_of(id);
        let block = {
            let mut slot = self.slots[home].lock().expect("kv shard lock poisoned");
            if let Some(&holder) = slot.leased_to.get(&id) {
                bail!(
                    "block {id} is exclusively leased to machine {holder} — the store is \
                     mid-training; serve from a quiescent store"
                );
            }
            let resident = slot.resident.get(&id).cloned();
            match resident {
                Some(b) => b,
                // Spilled blocks are decoded for the reader but *not*
                // promoted: residency stays a pure function of training
                // history, untouched by serving traffic.
                None => self
                    .peek_spilled(&mut slot, home, id)?
                    .with_context(|| format!("block {id} not in store"))?,
            }
        };
        // Length-only metering: a starved serving cache reads blocks per
        // token, so the O(block) encode allocation stays off this path.
        self.meter.lock().expect("kv meter lock poisoned").record(
            home,
            reader_machine,
            wire::encoded_block_len(&block),
            TransferKind::BlockRead,
        );
        Ok(block)
    }

    /// Arm a paging fault: the next `count` calls to
    /// [`KvStore::read_block`] for `id` fail with a typed
    /// [`MpldaError::ReadFault`] instead of copying the block. Faults are
    /// *sticky* across `count` reads because the serving tier's cache
    /// warm-up touches blocks ahead of fold-in; arm generously and
    /// [`KvStore::clear_read_faults`] when done.
    pub fn inject_read_fault(&self, id: u32, count: usize) {
        if count == 0 {
            return;
        }
        self.read_faults
            .lock()
            .expect("kv faults lock poisoned")
            .insert(id, count);
    }

    /// Disarm every fault set by [`KvStore::inject_read_fault`].
    pub fn clear_read_faults(&self) {
        self.read_faults.lock().expect("kv faults lock poisoned").clear();
    }

    /// Advance the round clock. The driver calls this at every round end;
    /// lease ages in [`KvStore::expired_leases`] are measured in these
    /// ticks.
    pub fn advance_round(&self) {
        self.clock.fetch_add(1, Ordering::Relaxed);
    }

    /// Outstanding leases older than `timeout_rounds` round boundaries —
    /// strictly older: a lease taken during round `r` and committed by the
    /// end of round `r + timeout_rounds` is *within* its deadline. (That
    /// is what keeps healthy pipelined prefetches — staged in round `r`,
    /// committed in round `r+1` — alive under `timeout_rounds = 1`.)
    pub fn expired_leases(&self, timeout_rounds: u64) -> Vec<u32> {
        let now = self.clock.load(Ordering::Relaxed);
        let mut expired = Vec::new();
        for slot in &self.slots {
            let slot = slot.lock().expect("kv shard lock poisoned");
            for (&id, &at) in &slot.leased_at {
                if now.saturating_sub(at) > timeout_rounds {
                    expired.push(id);
                }
            }
        }
        expired.sort_unstable();
        expired
    }

    /// Roll back an outstanding lease on `id`: the ledger entry is
    /// dropped and the recovery copy taken at lease time becomes resident
    /// again, so a surviving worker can lease the block next round. The
    /// dead holder's uncommitted round of updates on this block is lost —
    /// that is the recovery contract. Errors if the block is not leased
    /// or recovery was never enabled ([`KvStore::enable_recovery`]).
    pub fn revoke_lease(&self, id: u32) -> Result<()> {
        let home = self.home_of(id);
        let mut slot = self.slots[home].lock().expect("kv shard lock poisoned");
        let holder = match slot.leased_to.remove(&id) {
            Some(h) => h,
            None => bail!("cannot revoke block {id}: not leased"),
        };
        slot.leased_at.remove(&id);
        match slot.recovery.remove(&id) {
            Some(copy) => {
                slot.resident.insert(id, copy);
                slot.last_commit.insert(id, self.clock.load(Ordering::Relaxed));
                self.enforce_budget(&mut slot, home)?;
                Ok(())
            }
            None => {
                // Keep the ledger truthful before erroring.
                slot.leased_to.insert(id, holder);
                bail!(
                    "cannot revoke block {id}: no recovery copy \
                     (enable_recovery was not called before the lease)"
                )
            }
        }
    }

    /// Simulate losing machine `machine`'s shard-home: every block homed
    /// there (resident, recovery copies, and lease ledger entries alike)
    /// is promoted on the backup machine `(machine + 1) % machines`, and
    /// future traffic for those blocks flows to/from the backup. Block
    /// *contents* are untouched — this models replica promotion in the
    /// distributed hash table, so no recovery traffic is metered and the
    /// sampled trajectory is unchanged. Returns the relocated block ids.
    pub fn fail_home(&self, machine: usize) -> Result<Vec<u32>> {
        if self.slots.len() < 2 {
            bail!("cannot fail machine {machine}: single-machine store has no backup");
        }
        if machine >= self.slots.len() {
            bail!("cannot fail machine {machine}: store spans {} machines", self.slots.len());
        }
        let backup = (machine + 1) % self.slots.len();
        // Lock order mirrors every other path: overrides first, then
        // slots (two of them, by index, to stay deadlock-free).
        let mut overrides = self.home_overrides.lock().expect("kv overrides lock poisoned");
        let (lo, hi) = (machine.min(backup), machine.max(backup));
        let mut guard_lo = self.slots[lo].lock().expect("kv shard lock poisoned");
        let mut guard_hi = self.slots[hi].lock().expect("kv shard lock poisoned");
        let (failed, target) = if machine == lo {
            (&mut *guard_lo, &mut *guard_hi)
        } else {
            (&mut *guard_hi, &mut *guard_lo)
        };
        let mut moved: Vec<u32> = failed
            .resident
            .keys()
            .chain(failed.leased_to.keys())
            .copied()
            .collect();
        target.resident.append(&mut failed.resident);
        target.leased_to.append(&mut failed.leased_to);
        target.leased_at.append(&mut failed.leased_at);
        target.recovery.append(&mut failed.recovery);
        target.last_commit.append(&mut failed.last_commit);
        // The failed machine's disk segment dies with it: its spilled
        // blocks are recalled from the replica view (the segment *is* the
        // durable copy in this simulation) onto the backup as resident
        // blocks, then the backup's own budget re-spills whatever doesn't
        // fit — so the tier invariant survives failover.
        if let Some(disk) = failed.disk.as_mut() {
            for id in disk.block_ids() {
                let (encoding, payload) = disk
                    .read(id)
                    .and_then(|r| {
                        r.with_context(|| format!("indexed spilled block {id} vanished"))
                    })
                    .with_context(|| format!("recalling spilled block {id} during failover"))?;
                let block = codec::decode_block(&payload, encoding)
                    .with_context(|| format!("decoding spilled block {id} during failover"))?;
                self.meter.lock().expect("kv meter lock poisoned").record(
                    machine,
                    machine,
                    payload.len() as u64,
                    TransferKind::BlockRecall,
                );
                target.resident.insert(id, block);
                moved.push(id);
            }
            disk.clear()?;
        }
        failed.spilled_bytes.clear();
        moved.sort_unstable();
        moved.dedup();
        for &id in &moved {
            overrides.insert(id, backup);
        }
        self.enforce_budget(target, backup)?;
        Ok(moved)
    }

    /// Heap bytes of a resident (non-leased) block, or `None` if the block
    /// is currently leased out (or unknown). The pipelined engine uses this
    /// for staging-budget checks *before* paying for a prefetch. A block
    /// spilled to the disk tier still answers — with the content bytes it
    /// had at spill time, which (because [`crate::model::SparseRow::bytes`]
    /// is content-pure) equals what it will weigh when recalled — so the
    /// engine's budget arithmetic is identical whether or not the tier is
    /// attached.
    pub fn resident_block_bytes(&self, id: u32) -> Option<u64> {
        let slot = self.slot(id);
        slot.resident
            .get(&id)
            .map(|b| b.bytes())
            .or_else(|| slot.spilled_bytes.get(&id).copied())
    }

    /// Snapshot the topic totals (round-start sync of §3.3).
    pub fn read_totals(&self, worker_machine: usize) -> TopicCounts {
        let snapshot = self.totals.lock().expect("kv totals lock poisoned").clone();
        let bytes = wire::encode_totals(&snapshot).len() as u64;
        self.meter.lock().expect("kv meter lock poisoned").record(
            self.totals_home,
            worker_machine,
            bytes,
            TransferKind::TotalsRead,
        );
        snapshot
    }

    /// Merge a worker's signed `C_k` delta (round-end).
    pub fn merge_totals_delta(&self, delta: &TopicCounts, worker_machine: usize) {
        let bytes = wire::encode_totals(delta).len() as u64;
        {
            let mut meter = self.meter.lock().expect("kv meter lock poisoned");
            meter.record(worker_machine, self.totals_home, bytes, TransferKind::PsSync);
            // Classified as TotalsMerge for reporting:
            meter.record(worker_machine, self.totals_home, 0, TransferKind::TotalsMerge);
        }
        self.totals.lock().expect("kv totals lock poisoned").merge(delta);
    }

    /// Clone of the authoritative totals (truth `T` of the Fig 3 metric).
    pub fn totals_snapshot(&self) -> TopicCounts {
        self.totals.lock().expect("kv totals lock poisoned").clone()
    }

    /// Number of blocks currently leased out.
    pub fn num_leased(&self) -> usize {
        self.slots
            .iter()
            .map(|s| s.lock().expect("kv shard lock poisoned").leased_to.len())
            .sum()
    }

    /// Total bytes moved so far (all transfer kinds).
    pub fn total_bytes(&self) -> u64 {
        self.meter.lock().expect("kv meter lock poisoned").total_bytes()
    }

    /// Bytes moved so far for one transfer kind.
    pub fn bytes_of(&self, kind: TransferKind) -> u64 {
        self.meter.lock().expect("kv meter lock poisoned").bytes_of(kind)
    }

    /// Meter real socket bytes the distributed transport moved for
    /// `machine` — one of the out-of-band transport kinds
    /// ([`TransferKind::TaskDelta`]/[`TransferKind::TaskFull`]/
    /// [`TransferKind::ResultDelta`]/[`TransferKind::ResultFull`]).
    /// Never becomes a flow and never counts toward
    /// [`KvStore::network_bytes`]: the simulated network already timed
    /// the logical transfers these frames realize.
    pub fn record_transport(&self, machine: usize, bytes: u64, what: TransferKind) {
        debug_assert!(matches!(
            what,
            TransferKind::TaskDelta
                | TransferKind::TaskFull
                | TransferKind::ResultDelta
                | TransferKind::ResultFull
        ));
        self.meter
            .lock()
            .expect("kv meter lock poisoned")
            .record(machine, machine, bytes, what);
    }

    /// Bytes moved overlapped with compute (prefetch traffic) — see
    /// [`super::traffic::TrafficMeter::overlapped_bytes`].
    pub fn overlapped_bytes(&self) -> u64 {
        self.meter.lock().expect("kv meter lock poisoned").overlapped_bytes()
    }

    /// Take the pending transfers (for a phase's network timing) as flows.
    pub fn drain_flows(&self) -> Vec<Flow> {
        self.meter.lock().expect("kv meter lock poisoned").drain_flows()
    }

    /// Snapshot of the pending (un-drained) transfers.
    pub fn pending_transfers(&self) -> Vec<Transfer> {
        self.meter.lock().expect("kv meter lock poisoned").pending().to_vec()
    }

    /// Visit every resident (non-leased) block — the quiescent model view
    /// used by the driver's log-likelihood pass. The visitor runs with all
    /// shard locks held; iteration order is (home machine, block id).
    ///
    /// Spilled blocks are decoded and merged into each home's id order, so
    /// the visitor sees the same blocks in the same order whether or not
    /// the disk tier is attached — floating-point summation order in the
    /// log-likelihood pass is part of the bitwise-determinism bar. The
    /// decode is **unmetered**: a fully resident store pays nothing for
    /// this silent read-only pass, so a starved store must not either.
    pub fn with_resident_blocks<R>(
        &self,
        f: impl FnOnce(&mut dyn Iterator<Item = &ModelBlock>) -> R,
    ) -> R {
        let mut guards: Vec<MutexGuard<'_, MachineShard>> = self
            .slots
            .iter()
            .map(|s| s.lock().expect("kv shard lock poisoned"))
            .collect();
        let spilled: Vec<Vec<ModelBlock>> = guards
            .iter_mut()
            .map(|g| {
                let Some(disk) = g.disk.as_mut() else { return Vec::new() };
                disk.block_ids()
                    .into_iter()
                    .map(|id| {
                        let (encoding, payload) = disk
                            .read(id)
                            .and_then(|r| r.context("indexed spilled block vanished"))
                            .expect("reading spilled block for quiescent view");
                        codec::decode_block(&payload, encoding)
                            .expect("spilled block payload must decode")
                    })
                    .collect()
            })
            .collect();
        let per_home: Vec<Vec<&ModelBlock>> = guards
            .iter()
            .zip(spilled.iter())
            .map(|(g, sp)| {
                let mut v: Vec<&ModelBlock> = g.resident.values().chain(sp.iter()).collect();
                v.sort_unstable_by_key(|b| b.id);
                v
            })
            .collect();
        let mut it = per_home.iter().flat_map(|v| v.iter().copied());
        f(&mut it)
    }

    /// Bytes of shard storage on each machine (memory accounting).
    /// Recovery copies held for outstanding leases count against their
    /// home machine — that is the RAM price of fault tolerance.
    pub fn shard_bytes(&self, machines: usize) -> Vec<u64> {
        let mut per = vec![0u64; machines];
        for (home, slot) in self.slots.iter().enumerate() {
            let slot = slot.lock().expect("kv shard lock poisoned");
            let bytes: u64 = slot.resident.values().map(|b| b.bytes()).sum();
            let recovery: u64 = slot.recovery.values().map(|b| b.bytes()).sum();
            per[home] += bytes + recovery;
        }
        per
    }

    /// Heap bytes of the **resident tier only** on each machine — the
    /// working set the spill policy keeps under
    /// `storage.resident_budget_mib`, excluding recovery copies (which
    /// stay under [`crate::cluster::MemCategory::KvShard`]). This is what
    /// the driver charges to [`crate::cluster::MemCategory::Resident`].
    pub fn resident_tier_bytes(&self, machines: usize) -> Vec<u64> {
        let mut per = vec![0u64; machines];
        for (home, slot) in self.slots.iter().enumerate() {
            let slot = slot.lock().expect("kv shard lock poisoned");
            per[home] += slot.resident.values().map(|b| b.bytes()).sum::<u64>();
        }
        per
    }

    /// Is block `id` currently on the disk tier (spilled, not resident)?
    pub fn is_spilled(&self, id: u32) -> bool {
        self.slot(id).spilled_bytes.contains_key(&id)
    }

    /// Every spill so far, in eviction order — the determinism witness:
    /// two identical runs must produce identical sequences, because the
    /// victim choice is a pure function of (last-commit round, block id).
    pub fn spill_sequence(&self) -> Vec<u32> {
        self.spill_log.lock().expect("kv spill log poisoned").clone()
    }

    /// Bytes that actually crossed the network (total minus disk-tier
    /// spill/recall traffic) — see
    /// [`super::traffic::TrafficMeter::network_bytes`].
    pub fn network_bytes(&self) -> u64 {
        self.meter.lock().expect("kv meter lock poisoned").network_bytes()
    }

    /// Number of transfers recorded so far for one kind — the serve tier
    /// reports disk-recall *counts* next to recall bytes.
    pub fn count_of(&self, kind: TransferKind) -> u64 {
        self.meter.lock().expect("kv meter lock poisoned").count_of(kind)
    }

    /// Validate internal consistency: every block either resident or
    /// leased; totals match the column sums of resident blocks only if
    /// nothing is leased.
    pub fn check_quiescent_consistency(&self, num_topics: usize) -> Result<()> {
        let leased = self.num_leased();
        if leased != 0 {
            bail!("store not quiescent: {leased} blocks leased");
        }
        let mut sums = vec![0i64; num_topics];
        self.with_resident_blocks(|blocks| {
            for b in blocks {
                for (k, s) in b.column_sums(num_topics).into_iter().enumerate() {
                    sums[k] += s;
                }
            }
        });
        let totals = self.totals_snapshot();
        if sums != totals.as_slice() {
            bail!(
                "totals out of sync with blocks: blocks={sums:?} totals={:?}",
                totals.as_slice()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::Config;
    use crate::util::rng::Pcg64;

    fn setup(num_blocks: usize, machines: usize) -> KvStore {
        let cfg = Config::from_str(&format!(
            "[cluster]\npreset = \"custom\"\nmachines = {machines}"
        ))
        .unwrap();
        let spec = ClusterSpec::from_config(&cfg.cluster);
        let mut rng = Pcg64::new(1);
        let k = 8;
        let mut totals = TopicCounts::zeros(k);
        let blocks: Vec<ModelBlock> = (0..num_blocks as u32)
            .map(|id| {
                let mut b = ModelBlock::empty(id, id * 10, (id + 1) * 10);
                for w in b.lo..b.hi {
                    for _ in 0..rng.next_below(5) {
                        let t = rng.next_below(k as u64) as u32;
                        b.row_mut(w).inc(t);
                        totals.inc(t as usize);
                    }
                }
                b
            })
            .collect();
        let shards = ShardMap::round_robin(num_blocks, &spec);
        KvStore::new(blocks, totals, shards)
    }

    #[test]
    fn lease_commit_cycle() {
        let kv = setup(4, 2);
        let b = kv.lease_block(2, 1).unwrap();
        assert_eq!(kv.num_leased(), 1);
        kv.commit_block(b, 1).unwrap();
        assert_eq!(kv.num_leased(), 0);
        kv.check_quiescent_consistency(8).unwrap();
        assert!(kv.total_bytes() > 0);
    }

    #[test]
    fn stage_is_a_lease_metered_as_overlapped() {
        let kv = setup(4, 2);
        let fetch_before = kv.bytes_of(TransferKind::BlockFetch);
        let (b, receipt) = kv.stage_block(2, 1).unwrap();
        // Same lease ledger as a normal fetch: the block has one holder.
        assert_eq!(kv.num_leased(), 1);
        let err = kv.lease_block(2, 0).unwrap_err().to_string();
        assert!(err.contains("already leased"), "{err}");
        // Metered as prefetch, not fetch; receipt matches the meter.
        assert_eq!(kv.bytes_of(TransferKind::BlockFetch), fetch_before);
        assert_eq!(kv.bytes_of(TransferKind::BlockPrefetch), receipt.bytes);
        assert_eq!(kv.overlapped_bytes(), receipt.bytes);
        assert_eq!(receipt.dst, 1);
        assert!(receipt.bytes > 0);
        kv.commit_block(b, 1).unwrap();
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn receipts_mirror_recorded_flows() {
        let kv = setup(4, 2);
        let (b, lease) = kv.lease_block_with_receipt(1, 0).unwrap();
        let commit = kv.commit_block_with_receipt(b, 0).unwrap();
        // Commit is the reverse direction of the lease, same payload shape.
        assert_eq!(lease.src, commit.dst);
        assert_eq!(lease.dst, commit.src);
        assert!(lease.bytes > 0 && commit.bytes > 0);
        let flows = kv.drain_flows();
        assert!(flows.contains(&lease.flow()));
        assert!(flows.contains(&commit.flow()));
    }

    #[test]
    fn resident_block_bytes_tracks_leases() {
        let kv = setup(3, 2);
        let before = kv.resident_block_bytes(0).unwrap();
        assert!(before > 0);
        let b = kv.lease_block(0, 0).unwrap();
        assert_eq!(kv.resident_block_bytes(0), None);
        kv.commit_block(b, 0).unwrap();
        assert_eq!(kv.resident_block_bytes(0), Some(before));
    }

    #[test]
    fn commit_invalidates_alias_cache() {
        // Proposal tables are lease-scoped: whatever the holder cached on
        // the block must be gone by the next lease (the rows changed), so
        // staged/prefetched blocks always carry fresh tables.
        let kv = setup(2, 2);
        let mut b = kv.lease_block(0, 0).unwrap();
        b.alias.ensure(b.rows.len(), 0).build(0, &b.rows[0], &mut Vec::new());
        assert!(b.alias_bytes() > 0);
        kv.commit_block(b, 0).unwrap();
        let b2 = kv.lease_block(0, 0).unwrap();
        assert_eq!(b2.alias_bytes(), 0, "commit must clear the alias cache");
        kv.commit_block(b2, 0).unwrap();
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn read_block_is_a_concurrent_copy() {
        let kv = setup(4, 2);
        let before = kv.bytes_of(TransferKind::BlockRead);
        // Two "concurrent" readers: both get full copies, nothing leases.
        let a = kv.read_block(2, 0).unwrap();
        let b = kv.read_block(2, 1).unwrap();
        assert_eq!(a, b);
        assert_eq!(kv.num_leased(), 0);
        assert!(kv.bytes_of(TransferKind::BlockRead) > before);
        // The original is untouched: an exclusive lease still works …
        let owned = kv.lease_block(2, 0).unwrap();
        assert_eq!(owned, a);
        // … and while it is out, serving reads fail loudly.
        let err = kv.read_block(2, 1).unwrap_err().to_string();
        assert!(err.contains("exclusively leased"), "{err}");
        kv.commit_block(owned, 0).unwrap();
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn read_block_copies_do_not_alias_store_state() {
        // Mutating a serving copy must never reach the store.
        let kv = setup(2, 2);
        let mut copy = kv.read_block(0, 0).unwrap();
        copy.row_mut(copy.lo).inc(7);
        drop(copy);
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn commit_clears_alias_on_every_return_path() {
        // Direct coverage of the commit-time alias invalidation contract
        // (previously only exercised indirectly through pipeline
        // determinism): whatever the holder cached must be gone after
        // `commit_block`, `commit_block_with_receipt`, and the staged
        // re-lease the pipelined engine performs.
        let kv = setup(2, 2);

        // Plain commit.
        let mut b = kv.lease_block(0, 0).unwrap();
        b.alias.ensure(b.rows.len(), 0).build(0, &b.rows[0], &mut Vec::new());
        assert!(b.alias_bytes() > 0);
        kv.commit_block(b, 0).unwrap();
        let fresh = kv.lease_block(0, 0).unwrap();
        assert_eq!(fresh.alias_bytes(), 0, "plain commit must clear the alias cache");
        kv.commit_block(fresh, 0).unwrap();

        // Receipt-returning commit (the pipelined flusher's path).
        let mut b = kv.lease_block(0, 1).unwrap();
        b.alias.ensure(b.rows.len(), 0).build(0, &b.rows[0], &mut Vec::new());
        kv.commit_block_with_receipt(b, 1).unwrap();
        let staged = kv.stage_block(0, 0).unwrap().0;
        assert_eq!(staged.alias_bytes(), 0, "staged re-lease must carry a fresh alias slot");
        kv.commit_block(staged, 0).unwrap();

        // Serving reads after a commit see no stale alias either.
        let read = kv.read_block(0, 0).unwrap();
        assert_eq!(read.alias_bytes(), 0);
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn double_lease_rejected() {
        let kv = setup(4, 2);
        let _b = kv.lease_block(0, 0).unwrap();
        let err = kv.lease_block(0, 1).unwrap_err().to_string();
        assert!(err.contains("already leased"), "{err}");
    }

    #[test]
    fn commit_from_wrong_machine_rejected() {
        let kv = setup(4, 2);
        let b = kv.lease_block(0, 0).unwrap();
        assert!(kv.commit_block(b, 1).is_err());
        // Ledger intact: the lease is still attributed to machine 0.
        assert_eq!(kv.num_leased(), 1);
    }

    #[test]
    fn commit_unleased_rejected() {
        let kv = setup(4, 2);
        let b = ModelBlock::empty(0, 0, 10);
        assert!(kv.commit_block(b, 0).is_err());
    }

    #[test]
    fn totals_round_trip() {
        let kv = setup(2, 2);
        let snap = kv.read_totals(1);
        let mut delta = TopicCounts::zeros(8);
        delta.inc(3);
        delta.dec(0);
        kv.merge_totals_delta(&delta, 1);
        let now = kv.totals_snapshot();
        assert_eq!(now.get(3), snap.get(3) + 1);
        assert_eq!(now.get(0), snap.get(0) - 1);
    }

    #[test]
    fn quiescent_check_detects_leak() {
        let kv = setup(2, 2);
        let _b = kv.lease_block(0, 0).unwrap();
        assert!(kv.check_quiescent_consistency(8).is_err());
    }

    #[test]
    fn mutated_commit_breaks_totals_until_delta_merged() {
        // Committing a mutated block without merging the C_k delta leaves
        // the store inconsistent — the §3.3 channel is what fixes it.
        let kv = setup(2, 2);
        let mut b = kv.lease_block(0, 0).unwrap();
        b.row_mut(b.lo).inc(5);
        kv.commit_block(b, 0).unwrap();
        assert!(kv.check_quiescent_consistency(8).is_err());
        let mut delta = TopicCounts::zeros(8);
        delta.inc(5);
        kv.merge_totals_delta(&delta, 0);
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn concurrent_round_from_shared_reference() {
        // The shard-locked store supports a whole round — totals read,
        // lease, commit, delta merge — driven from plain `&KvStore` on
        // many threads at once, one block per "worker".
        let blocks = 8;
        let kv = setup(blocks, 4);
        let before = kv.totals_snapshot();
        std::thread::scope(|s| {
            for w in 0..blocks as u32 {
                let kv = &kv;
                s.spawn(move || {
                    let machine = (w as usize) % 4;
                    let _snap = kv.read_totals(machine);
                    let mut b = kv.lease_block(w, machine).unwrap();
                    b.row_mut(b.lo).inc((w % 8) as u32);
                    kv.commit_block(b, machine).unwrap();
                    let mut delta = TopicCounts::zeros(8);
                    delta.inc((w % 8) as usize);
                    kv.merge_totals_delta(&delta, machine);
                });
            }
        });
        assert_eq!(kv.num_leased(), 0);
        kv.check_quiescent_consistency(8).unwrap();
        let after = kv.totals_snapshot();
        let sum = |t: &TopicCounts| t.as_slice().iter().sum::<i64>();
        assert_eq!(sum(&after), sum(&before) + blocks as i64);
    }

    fn setup_recovering(num_blocks: usize, machines: usize) -> KvStore {
        let mut kv = setup(num_blocks, machines);
        kv.enable_recovery();
        kv
    }

    #[test]
    fn expired_lease_is_revoked_and_block_restored() {
        let kv = setup_recovering(4, 2);
        let snapshot = kv.read_block(2, 0).unwrap();
        let mut b = kv.lease_block(2, 1).unwrap();
        b.row_mut(b.lo).inc(3); // dead worker's uncommitted mutation
        // Healthy within the deadline: one boundary with timeout 1.
        kv.advance_round();
        assert!(kv.expired_leases(1).is_empty());
        // One more boundary without a commit → expired.
        kv.advance_round();
        assert_eq!(kv.expired_leases(1), vec![2]);
        kv.revoke_lease(2).unwrap();
        assert_eq!(kv.num_leased(), 0);
        // The pre-lease copy is back; the holder's mutation is gone.
        assert_eq!(kv.read_block(2, 0).unwrap(), snapshot);
        kv.check_quiescent_consistency(8).unwrap();
        // The zombie's late commit is now a protocol violation.
        assert!(kv.commit_block(b, 1).is_err());
    }

    #[test]
    fn staged_leases_age_like_any_other() {
        // A staged prefetch taken in round r and committed during round
        // r+1 survives timeout 1; one stranded past that expires.
        let kv = setup_recovering(4, 2);
        let (b, _r) = kv.stage_block(1, 0).unwrap();
        kv.advance_round();
        assert!(kv.expired_leases(1).is_empty(), "healthy handoff must not expire");
        kv.commit_block(b, 0).unwrap();
        let (_stranded, _r) = kv.stage_block(3, 0).unwrap();
        kv.advance_round();
        kv.advance_round();
        assert_eq!(kv.expired_leases(1), vec![3]);
        kv.revoke_lease(3).unwrap();
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn revoke_without_recovery_copy_fails_cleanly() {
        let kv = setup(4, 2); // recovery NOT enabled
        let _b = kv.lease_block(0, 0).unwrap();
        let err = kv.revoke_lease(0).unwrap_err().to_string();
        assert!(err.contains("no recovery copy"), "{err}");
        // Ledger still truthful.
        assert_eq!(kv.num_leased(), 1);
        let err = kv.revoke_lease(2).unwrap_err().to_string();
        assert!(err.contains("not leased"), "{err}");
    }

    #[test]
    fn recovery_copies_count_toward_shard_bytes() {
        let kv = setup_recovering(4, 2);
        let quiescent: u64 = kv.shard_bytes(2).iter().sum();
        let b = kv.lease_block(2, 1).unwrap();
        let with_lease: u64 = kv.shard_bytes(2).iter().sum();
        assert_eq!(with_lease, quiescent, "recovery copy keeps the bytes home");
        kv.commit_block(b, 1).unwrap();
        assert_eq!(kv.shard_bytes(2).iter().sum::<u64>(), quiescent);
    }

    #[test]
    fn injected_read_faults_are_typed_counted_and_clearable() {
        use crate::error::MpldaError;
        let kv = setup(4, 2);
        kv.inject_read_fault(2, 2);
        for _ in 0..2 {
            let err = kv.read_block(2, 0).unwrap_err();
            assert_eq!(
                err.downcast_ref::<MpldaError>(),
                Some(&MpldaError::ReadFault { block: 2 })
            );
        }
        // Count exhausted: reads heal.
        assert!(kv.read_block(2, 0).is_ok());
        // Other blocks were never affected.
        kv.inject_read_fault(2, 1000);
        assert!(kv.read_block(3, 0).is_ok());
        kv.clear_read_faults();
        assert!(kv.read_block(2, 0).is_ok());
    }

    #[test]
    fn fail_home_promotes_blocks_on_backup() {
        let kv = setup_recovering(4, 2);
        let before: Vec<ModelBlock> =
            (0..4).map(|id| kv.read_block(id, 0).unwrap()).collect();
        // Machine 0 homes blocks 0 and 2 under round-robin; lease one of
        // them first so the ledger relocates too.
        let leased = kv.lease_block(0, 1).unwrap();
        let moved = kv.fail_home(0).unwrap();
        assert_eq!(moved, vec![0, 2]);
        // All shard bytes now live on machine 1.
        let per = kv.shard_bytes(2);
        assert_eq!(per[0], 0);
        assert!(per[1] > 0);
        // The relocated ledger still accepts the in-flight commit …
        kv.commit_block(leased, 1).unwrap();
        // … contents are unchanged, and new reads flow from the backup.
        for want in &before {
            assert_eq!(&kv.read_block(want.id, 0).unwrap(), want);
        }
        kv.check_quiescent_consistency(8).unwrap();
        // Lease/commit cycles keep working against the promoted home.
        let b = kv.lease_block(2, 0).unwrap();
        kv.commit_block(b, 0).unwrap();
        kv.check_quiescent_consistency(8).unwrap();
    }

    #[test]
    fn fail_home_needs_a_backup_machine() {
        let kv = setup(2, 1);
        assert!(kv.fail_home(0).is_err());
        let kv = setup(2, 2);
        assert!(kv.fail_home(7).is_err());
    }

    #[test]
    fn with_resident_blocks_visits_everything_once() {
        let kv = setup(6, 3);
        let ids = kv.with_resident_blocks(|blocks| {
            let mut ids: Vec<u32> = blocks.map(|b| b.id).collect();
            ids.sort_unstable();
            ids
        });
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    // ---- out-of-core tier ----

    use crate::storage::{Encoding, StorageOptions};
    use std::path::PathBuf;

    /// Attach the disk tier under a per-test temp dir (sparse codec).
    fn attach(kv: &mut KvStore, name: &str, budget: u64) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mplda_kv_{}_{}", name, std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        kv.attach_storage(StorageOptions {
            dir: dir.clone(),
            budget_bytes: budget,
            encoding: Encoding::Sparse,
        })
        .unwrap();
        dir
    }

    #[test]
    fn attach_requires_positive_budget() {
        let mut kv = setup(2, 1);
        let err = kv
            .attach_storage(StorageOptions {
                dir: std::env::temp_dir().join("mplda_kv_zero_budget"),
                budget_bytes: 0,
                encoding: Encoding::Wire,
            })
            .unwrap_err()
            .to_string();
        assert!(err.contains("budget"), "{err}");
        assert!(!kv.storage_attached());
    }

    #[test]
    fn attach_spills_down_to_budget_and_leases_recall() {
        let mut kv = setup(4, 2);
        let before: Vec<ModelBlock> =
            (0..4).map(|id| kv.read_block(id, 0).unwrap()).collect();
        let dir = attach(&mut kv, "recall", 1);
        // 1-byte budget: every home spills everything (oversized blocks
        // spill immediately, leaving the home empty but legal).
        assert!(kv.storage_attached());
        for id in 0..4u32 {
            assert!(kv.is_spilled(id), "block {id} should be spilled");
        }
        assert!(kv.bytes_of(TransferKind::BlockSpill) > 0);
        assert!(kv.resident_tier_bytes(2).iter().all(|&b| b <= 1));
        // Budget queries still answer for spilled blocks, with the
        // content bytes the block will weigh once recalled.
        assert_eq!(kv.resident_block_bytes(2), Some(before[2].bytes()));
        // Reads recall a copy without promoting.
        let copy = kv.read_block(2, 0).unwrap();
        assert_eq!(copy, before[2]);
        assert!(kv.is_spilled(2), "read_block must not promote");
        assert!(kv.bytes_of(TransferKind::BlockRecall) > 0);
        // Disk traffic is metered but never becomes a network flow.
        assert!(kv
            .pending_transfers()
            .iter()
            .all(|t| !matches!(t.what, TransferKind::BlockSpill | TransferKind::BlockRecall)));
        assert_eq!(
            kv.network_bytes(),
            kv.total_bytes()
                - kv.bytes_of(TransferKind::BlockSpill)
                - kv.bytes_of(TransferKind::BlockRecall)
        );
        // A lease recalls transparently; the commit re-spills.
        for want in &before {
            let b = kv.lease_block(want.id, 1).unwrap();
            assert_eq!(&b, want);
            kv.commit_block(b, 1).unwrap();
            assert!(kv.is_spilled(want.id), "commit over budget must re-spill");
        }
        kv.check_quiescent_consistency(8).unwrap();
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn budget_holds_after_every_commit() {
        let mut kv = setup(6, 2);
        // Room for roughly one block per home: the rest must spill.
        let budget = (0..6).filter_map(|id| kv.resident_block_bytes(id)).max().unwrap();
        let dir = attach(&mut kv, "budget", budget);
        assert!(!kv.spill_sequence().is_empty(), "attach must spill past the budget");
        for round in 0..3u64 {
            for id in 0..6u32 {
                let machine = (id as usize) % 2;
                let mut b = kv.lease_block(id, machine).unwrap();
                b.row_mut(b.lo).inc(id % 8);
                kv.commit_block(b, machine).unwrap();
                for &bytes in &kv.resident_tier_bytes(2) {
                    assert!(
                        bytes <= budget,
                        "round {round}: resident {bytes} > budget {budget}"
                    );
                }
            }
            kv.advance_round();
        }
        // Re-sync the totals the incs drifted, then deep-check the store.
        let mut delta = TopicCounts::zeros(8);
        for _ in 0..3 {
            for id in 0..6u32 {
                delta.inc((id % 8) as usize);
            }
        }
        kv.merge_totals_delta(&delta, 0);
        kv.check_quiescent_consistency(8).unwrap();
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn spill_sequences_are_identical_across_identical_runs() {
        // The eviction-determinism satellite: two runs with identical
        // histories (but distinct disk dirs) must evict in the same order.
        let run = |name: &str| {
            let mut kv = setup(6, 3);
            let dir = attach(&mut kv, name, 1);
            for round in 0..4u64 {
                for id in 0..6u32 {
                    let machine = (id as usize) % 3;
                    let mut b = kv.lease_block(id, machine).unwrap();
                    b.row_mut(b.lo).inc((round % 8) as u32);
                    kv.commit_block(b, machine).unwrap();
                }
                kv.advance_round();
            }
            let seq = kv.spill_sequence();
            std::fs::remove_dir_all(dir).ok();
            seq
        };
        let a = run("det_a");
        let b = run("det_b");
        assert!(!a.is_empty());
        assert_eq!(a, b, "eviction order must be a pure function of history");
    }

    #[test]
    fn fail_home_relocates_spilled_blocks() {
        let mut kv = setup(4, 2);
        kv.enable_recovery();
        let before: Vec<ModelBlock> =
            (0..4).map(|id| kv.read_block(id, 0).unwrap()).collect();
        let dir = attach(&mut kv, "failover", 1);
        assert!(kv.is_spilled(0) && kv.is_spilled(2));
        let moved = kv.fail_home(0).unwrap();
        assert_eq!(moved, vec![0, 2]);
        // Contents survive the failover, re-homed (and re-spilled under
        // the backup's budget) on machine 1.
        for want in &before {
            assert_eq!(&kv.read_block(want.id, 0).unwrap(), want);
        }
        kv.check_quiescent_consistency(8).unwrap();
        let b = kv.lease_block(0, 0).unwrap();
        kv.commit_block(b, 0).unwrap();
        kv.check_quiescent_consistency(8).unwrap();
        std::fs::remove_dir_all(dir).ok();
    }
}
