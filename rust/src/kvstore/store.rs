//! The sharded block store with lease semantics.
//!
//! Operations (all meter traffic against the requesting worker's machine):
//!
//! * [`KvStore::lease_block`] — move a block out of its shard to a worker.
//!   A block can have **at most one holder**; double-lease is a protocol
//!   violation and errors loudly (this is the §3.2 disjointness guarantee
//!   made mechanical).
//! * [`KvStore::commit_block`] — return the (mutated) block.
//! * [`KvStore::read_totals`] / [`KvStore::merge_totals_delta`] — the §3.3
//!   relaxed-consistency channel for `C_k`: snapshot at round start, merge
//!   signed deltas at round end.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::model::wire;
use crate::model::{ModelBlock, TopicCounts};

use super::shard::ShardMap;
use super::traffic::{TrafficMeter, TransferKind};

/// Sharded in-memory store of model blocks + topic totals.
pub struct KvStore {
    shards: ShardMap,
    /// Blocks currently resident (not leased), by id.
    resident: BTreeMap<u32, ModelBlock>,
    /// Holder of each leased block.
    leased_to: BTreeMap<u32, usize>,
    /// Authoritative topic totals (machine hosting it = totals_home).
    totals: TopicCounts,
    totals_home: usize,
    meter: TrafficMeter,
}

impl KvStore {
    /// Build from the initial blocks and totals.
    pub fn new(blocks: Vec<ModelBlock>, totals: TopicCounts, shards: ShardMap) -> KvStore {
        assert_eq!(blocks.len(), shards.num_blocks());
        let resident = blocks.into_iter().map(|b| (b.id, b)).collect();
        KvStore {
            shards,
            resident,
            leased_to: BTreeMap::new(),
            totals,
            totals_home: 0,
            meter: TrafficMeter::new(),
        }
    }

    /// Lease block `id` to a worker on `worker_machine`. Records the fetch
    /// flow `home(id) → worker_machine` sized by the block's wire encoding.
    pub fn lease_block(&mut self, id: u32, worker_machine: usize) -> Result<ModelBlock> {
        if let Some(&holder) = self.leased_to.get(&id) {
            bail!("protocol violation: block {id} already leased to machine {holder}");
        }
        let block = self
            .resident
            .remove(&id)
            .with_context(|| format!("block {id} not in store"))?;
        let bytes = wire::encode_block(&block).len() as u64;
        self.meter.record(
            self.shards.home(id as usize),
            worker_machine,
            bytes,
            TransferKind::BlockFetch,
        );
        self.leased_to.insert(id, worker_machine);
        Ok(block)
    }

    /// Commit a leased block back. Records the commit flow.
    pub fn commit_block(&mut self, block: ModelBlock, worker_machine: usize) -> Result<()> {
        match self.leased_to.remove(&block.id) {
            None => bail!("protocol violation: commit of unleased block {}", block.id),
            Some(holder) if holder != worker_machine => {
                bail!(
                    "protocol violation: block {} leased to machine {holder}, committed from {worker_machine}",
                    block.id
                );
            }
            Some(_) => {}
        }
        let bytes = wire::encode_block(&block).len() as u64;
        self.meter.record(
            worker_machine,
            self.shards.home(block.id as usize),
            bytes,
            TransferKind::BlockCommit,
        );
        self.resident.insert(block.id, block);
        Ok(())
    }

    /// Snapshot the topic totals (round-start sync of §3.3).
    pub fn read_totals(&mut self, worker_machine: usize) -> TopicCounts {
        let bytes = wire::encode_totals(&self.totals).len() as u64;
        self.meter
            .record(self.totals_home, worker_machine, bytes, TransferKind::TotalsRead);
        self.totals.clone()
    }

    /// Merge a worker's signed `C_k` delta (round-end).
    pub fn merge_totals_delta(&mut self, delta: &TopicCounts, worker_machine: usize) {
        let bytes = wire::encode_totals(delta).len() as u64;
        self.meter
            .record(worker_machine, self.totals_home, bytes, TransferKind::PsSync);
        // Classified as TotalsMerge for reporting:
        self.meter.record(worker_machine, self.totals_home, 0, TransferKind::TotalsMerge);
        self.totals.merge(delta);
    }

    /// Authoritative totals (truth `T` of the Fig 3 metric).
    pub fn totals(&self) -> &TopicCounts {
        &self.totals
    }

    /// Number of blocks currently leased out.
    pub fn num_leased(&self) -> usize {
        self.leased_to.len()
    }

    /// Traffic meter access (drained by the coordinator for timing).
    pub fn meter_mut(&mut self) -> &mut TrafficMeter {
        &mut self.meter
    }

    pub fn meter(&self) -> &TrafficMeter {
        &self.meter
    }

    /// Resident (non-leased) blocks — the quiescent model view used by the
    /// driver's log-likelihood pass.
    pub fn resident_blocks(&self) -> impl Iterator<Item = &ModelBlock> {
        self.resident.values()
    }

    /// Bytes of shard storage on each machine (memory accounting).
    pub fn shard_bytes(&self, machines: usize) -> Vec<u64> {
        let mut per = vec![0u64; machines];
        for (id, b) in &self.resident {
            per[self.shards.home(*id as usize)] += b.bytes();
        }
        per
    }

    /// Validate internal consistency: every block either resident or
    /// leased; totals match the column sums of resident blocks only if
    /// nothing is leased.
    pub fn check_quiescent_consistency(&self, num_topics: usize) -> Result<()> {
        if !self.leased_to.is_empty() {
            bail!("store not quiescent: {} blocks leased", self.leased_to.len());
        }
        let mut sums = vec![0i64; num_topics];
        for b in self.resident.values() {
            for (k, s) in b.column_sums(num_topics).into_iter().enumerate() {
                sums[k] += s;
            }
        }
        if sums != self.totals.as_slice() {
            bail!(
                "totals out of sync with blocks: blocks={sums:?} totals={:?}",
                self.totals.as_slice()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::Config;
    use crate::util::rng::Pcg64;

    fn setup(num_blocks: usize, machines: usize) -> KvStore {
        let cfg = Config::from_str(&format!(
            "[cluster]\npreset = \"custom\"\nmachines = {machines}"
        ))
        .unwrap();
        let spec = ClusterSpec::from_config(&cfg.cluster);
        let mut rng = Pcg64::new(1);
        let k = 8;
        let mut totals = TopicCounts::zeros(k);
        let blocks: Vec<ModelBlock> = (0..num_blocks as u32)
            .map(|id| {
                let mut b = ModelBlock::empty(id, id * 10, (id + 1) * 10);
                for w in b.lo..b.hi {
                    for _ in 0..rng.next_below(5) {
                        let t = rng.next_below(k as u64) as u32;
                        b.row_mut(w).inc(t);
                        totals.inc(t as usize);
                    }
                }
                b
            })
            .collect();
        let shards = ShardMap::round_robin(num_blocks, &spec);
        KvStore::new(blocks, totals, shards)
    }

    #[test]
    fn lease_commit_cycle() {
        let mut kv = setup(4, 2);
        let b = kv.lease_block(2, 1).unwrap();
        assert_eq!(kv.num_leased(), 1);
        kv.commit_block(b, 1).unwrap();
        assert_eq!(kv.num_leased(), 0);
        kv.check_quiescent_consistency(8).unwrap();
        assert!(kv.meter().total_bytes() > 0);
    }

    #[test]
    fn double_lease_rejected() {
        let mut kv = setup(4, 2);
        let _b = kv.lease_block(0, 0).unwrap();
        let err = kv.lease_block(0, 1).unwrap_err().to_string();
        assert!(err.contains("already leased"), "{err}");
    }

    #[test]
    fn commit_from_wrong_machine_rejected() {
        let mut kv = setup(4, 2);
        let b = kv.lease_block(0, 0).unwrap();
        assert!(kv.commit_block(b, 1).is_err());
    }

    #[test]
    fn commit_unleased_rejected() {
        let mut kv = setup(4, 2);
        let b = ModelBlock::empty(0, 0, 10);
        assert!(kv.commit_block(b, 0).is_err());
    }

    #[test]
    fn totals_round_trip() {
        let mut kv = setup(2, 2);
        let snap = kv.read_totals(1);
        let mut delta = TopicCounts::zeros(8);
        delta.inc(3);
        delta.dec(0);
        kv.merge_totals_delta(&delta, 1);
        assert_eq!(kv.totals().get(3), snap.get(3) + 1);
        assert_eq!(kv.totals().get(0), snap.get(0) - 1);
    }

    #[test]
    fn quiescent_check_detects_leak() {
        let mut kv = setup(2, 2);
        let _b = kv.lease_block(0, 0).unwrap();
        assert!(kv.check_quiescent_consistency(8).is_err());
    }

    #[test]
    fn mutated_commit_breaks_totals_until_delta_merged() {
        // Committing a mutated block without merging the C_k delta leaves
        // the store inconsistent — the §3.3 channel is what fixes it.
        let mut kv = setup(2, 2);
        let mut b = kv.lease_block(0, 0).unwrap();
        b.row_mut(b.lo).inc(5);
        kv.commit_block(b, 0).unwrap();
        assert!(kv.check_quiescent_consistency(8).is_err());
        let mut delta = TopicCounts::zeros(8);
        delta.inc(5);
        kv.merge_totals_delta(&delta, 0);
        kv.check_quiescent_consistency(8).unwrap();
    }
}
