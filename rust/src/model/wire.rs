//! Wire format for model state in flight (§3.2 "on-demand communication").
//!
//! Blocks and topic-total vectors are serialized when they move between a
//! worker and the KV-store; the **byte length of the encoding is what the
//! network simulator charges**, so the format matters for fidelity: like
//! the paper's C++ implementation we send sparse rows as varint-delta
//! streams, which makes block size proportional to `nnz`, not `V_block × K`.
//!
//! Layout (little-endian, LEB128 varints):
//! ```text
//! Block  := id:u32 lo:u32 hi:u32 stride:varint nrows:varint Row*
//! Row    := nnz:varint (topic_delta:varint count:varint)*
//! Totals := k:varint (zigzag(count):varint)*
//! ```

use anyhow::{bail, Result};

use super::block::ModelBlock;
use super::topic_counts::TopicCounts;
use super::word_topic::SparseRow;

/// Append a LEB128 varint.
#[inline]
pub fn put_varint(buf: &mut Vec<u8>, mut x: u64) {
    loop {
        let byte = (x & 0x7f) as u8;
        x >>= 7;
        if x == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Read a LEB128 varint.
#[inline]
pub fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut x = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&byte) = buf.get(*pos) else {
            bail!("varint truncated at {pos}");
        };
        *pos += 1;
        x |= ((byte & 0x7f) as u64) << shift;
        if byte & 0x80 == 0 {
            return Ok(x);
        }
        shift += 7;
        if shift >= 64 {
            bail!("varint overflow");
        }
    }
}

#[inline]
fn zigzag(x: i64) -> u64 {
    ((x << 1) ^ (x >> 63)) as u64
}

#[inline]
fn unzigzag(x: u64) -> i64 {
    ((x >> 1) as i64) ^ -((x & 1) as i64)
}

/// Encode a model block.
pub fn encode_block(block: &ModelBlock) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16 + block.nnz() * 3);
    buf.extend_from_slice(&block.id.to_le_bytes());
    buf.extend_from_slice(&block.lo.to_le_bytes());
    buf.extend_from_slice(&block.hi.to_le_bytes());
    put_varint(&mut buf, block.stride as u64);
    put_varint(&mut buf, block.rows.len() as u64);
    for row in &block.rows {
        put_varint(&mut buf, row.nnz() as u64);
        let mut prev = 0u32;
        for (k, c) in row.iter() {
            put_varint(&mut buf, (k - prev) as u64);
            put_varint(&mut buf, c as u64);
            prev = k;
        }
    }
    buf
}

/// Bytes a LEB128 varint of `x` occupies.
#[inline]
fn varint_len(x: u64) -> u64 {
    (((64 - (x | 1).leading_zeros() as u64) + 6) / 7).max(1)
}

/// Length of [`encode_block`]'s output **without materializing it** — the
/// serving tier meters read-lease traffic per block copy, sometimes once
/// per token (a starved cache), so the O(block) encode allocation must
/// stay off that path.
pub fn encoded_block_len(block: &ModelBlock) -> u64 {
    let mut len = 12 + varint_len(block.stride as u64) + varint_len(block.rows.len() as u64);
    for row in &block.rows {
        len += varint_len(row.nnz() as u64);
        let mut prev = 0u32;
        for (k, c) in row.iter() {
            len += varint_len((k - prev) as u64) + varint_len(c as u64);
            prev = k;
        }
    }
    len
}

/// Decode a model block.
pub fn decode_block(buf: &[u8]) -> Result<ModelBlock> {
    if buf.len() < 12 {
        bail!("block header truncated");
    }
    let id = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    let lo = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let hi = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    let mut pos = 12;
    let stride = get_varint(buf, &mut pos)? as u32;
    if stride == 0 {
        bail!("zero stride");
    }
    let nrows = get_varint(buf, &mut pos)? as usize;
    let expect = ((hi - lo) as usize).div_ceil(stride as usize);
    if nrows != expect {
        bail!("row count {nrows} does not match range [{lo},{hi}) stride {stride}");
    }
    let mut rows = Vec::with_capacity(nrows);
    for _ in 0..nrows {
        let nnz = get_varint(buf, &mut pos)? as usize;
        // Every entry costs at least two bytes (two varints): bound the
        // claimed count by the remaining buffer before any allocation
        // trusts it — a hostile varint fits a 64 MiB frame but can claim
        // 2^64 entries.
        if nnz > (buf.len() - pos) / 2 {
            bail!("row claims {nnz} entries but only {} bytes remain", buf.len() - pos);
        }
        let mut entries = Vec::with_capacity(nnz);
        let mut prev = 0u32;
        for _ in 0..nnz {
            let dk = get_varint(buf, &mut pos)? as u32;
            let c = get_varint(buf, &mut pos)? as u32;
            let k = prev + dk;
            entries.push((k, c));
            prev = k;
        }
        rows.push(SparseRow::from_entries(entries));
    }
    if pos != buf.len() {
        bail!("trailing bytes after block");
    }
    Ok(ModelBlock { id, lo, hi, stride, rows, alias: Default::default() })
}

/// Encode a topic-totals vector (or signed delta).
pub fn encode_totals(t: &TopicCounts) -> Vec<u8> {
    let mut buf = Vec::with_capacity(4 + t.num_topics() * 2);
    put_varint(&mut buf, t.num_topics() as u64);
    for &c in t.as_slice() {
        put_varint(&mut buf, zigzag(c));
    }
    buf
}

/// Decode a topic-totals vector.
pub fn decode_totals(buf: &[u8]) -> Result<TopicCounts> {
    let mut pos = 0;
    let k = get_varint(buf, &mut pos)? as usize;
    let mut counts = Vec::with_capacity(k);
    for _ in 0..k {
        counts.push(unzigzag(get_varint(buf, &mut pos)?));
    }
    if pos != buf.len() {
        bail!("trailing bytes after totals");
    }
    Ok(TopicCounts::from_vec(counts))
}

/// Wire size of a block without materializing the encoding — used by the
/// memory/traffic accountant for the full-scale extrapolations where we
/// never build the 21.8M-word table.
pub fn block_wire_size_estimate(nnz: u64, num_rows: u64) -> u64 {
    // header 12 + nrows varint (≤5) + per-row nnz varint (≈1) +
    // per-entry ≈ 1.5 (topic delta) + 1.5 (count) bytes on Zipf data.
    12 + 5 + num_rows + nnz * 3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_block(seed: u64, lo: u32, hi: u32, k: u64) -> ModelBlock {
        let mut rng = Pcg64::new(seed);
        let mut b = ModelBlock::empty(3, lo, hi);
        for w in lo..hi {
            let n = rng.next_below(20);
            for _ in 0..n {
                b.row_mut(w).inc(rng.next_below(k) as u32);
            }
        }
        b
    }

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let values = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        for &v in &values {
            put_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &values {
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn varint_truncation_detected() {
        let mut buf = Vec::new();
        put_varint(&mut buf, u64::MAX);
        buf.pop();
        let mut pos = 0;
        assert!(get_varint(&buf, &mut pos).is_err());
    }

    #[test]
    fn block_roundtrip() {
        let b = random_block(10, 100, 164, 50);
        let enc = encode_block(&b);
        let dec = decode_block(&enc).unwrap();
        assert_eq!(dec, b);
    }

    #[test]
    fn empty_block_roundtrip() {
        let b = ModelBlock::empty(0, 5, 9);
        let dec = decode_block(&encode_block(&b)).unwrap();
        assert_eq!(dec, b);
    }

    #[test]
    fn encoded_len_matches_encoding_exactly() {
        for (seed, lo, hi, k) in [(10u64, 100u32, 164u32, 50u64), (7, 0, 1, 2), (3, 0, 40, 1000)]
        {
            let b = random_block(seed, lo, hi, k);
            assert_eq!(
                encoded_block_len(&b),
                encode_block(&b).len() as u64,
                "seed {seed}"
            );
        }
        let empty = ModelBlock::empty(0, 5, 9);
        assert_eq!(encoded_block_len(&empty), encode_block(&empty).len() as u64);
    }

    #[test]
    fn totals_roundtrip_including_negatives() {
        let t = TopicCounts::from_vec(vec![5, -3, 0, 1_000_000, -42]);
        let dec = decode_totals(&encode_totals(&t)).unwrap();
        assert_eq!(dec, t);
    }

    #[test]
    fn wire_size_tracks_sparsity_not_dimensions() {
        // Same range, different densities — size must scale with nnz.
        let sparse = random_block(1, 0, 256, 1000);
        let mut dense = ModelBlock::empty(0, 0, 256);
        let mut rng = Pcg64::new(2);
        for w in 0..256u32 {
            for _ in 0..200 {
                dense.row_mut(w).inc(rng.next_below(1000) as u32);
            }
        }
        let s = encode_block(&sparse).len();
        let d = encode_block(&dense).len();
        assert!(d > s * 3, "dense={d} sparse={s}");
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode_block(&[1, 2, 3]).is_err());
        let b = random_block(4, 0, 10, 20);
        let mut enc = encode_block(&b);
        enc.push(0); // trailing byte
        assert!(decode_block(&enc).is_err());
    }

    #[test]
    fn estimate_is_within_2x_of_actual() {
        let b = random_block(9, 0, 500, 200);
        let actual = encode_block(&b).len() as u64;
        let est = block_wire_size_estimate(b.nnz() as u64, b.num_words() as u64);
        assert!(est >= actual / 2 && est <= actual * 2, "actual={actual} est={est}");
    }
}
