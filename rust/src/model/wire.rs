//! Wire format for model state in flight (§3.2 "on-demand communication").
//!
//! Blocks and topic-total vectors are serialized when they move between a
//! worker and the KV-store; the **byte length of the encoding is what the
//! network simulator charges**, so the format matters for fidelity: like
//! the paper's C++ implementation we send sparse rows as varint-delta
//! streams, which makes block size proportional to `nnz`, not `V_block × K`.
//!
//! Layout (little-endian, LEB128 varints):
//! ```text
//! Block  := id:u32 lo:u32 hi:u32 stride:varint nrows:varint Row*
//! Row    := nnz:varint (topic_delta:varint count:varint)*
//! Totals := k:varint (zigzag(count):varint)*
//! ```
//!
//! **Delta encodings** (the distributed protocol's round-trip payloads —
//! one Gibbs round touches O(tokens) entries of a block that costs
//! O(nnz) to ship whole, and a handful of `C_k` buckets out of `K`):
//! ```text
//! TotalsΔ := k:varint n:varint (idx_gap:varint zigzag(Δ):varint)*
//! BlockΔ  := id:u32 lo:u32 hi:u32 stride:varint nrows:varint
//!            (row_gap:varint n:varint (topic_gap:varint zigzag(Δ):varint)*)*
//! ```
//! Both are *lossless against a shared base*: `apply(base, encode(base,
//! new)) == new` bit for bit, which is what keeps the delta-shipping
//! distributed backend on the bitwise-equal-to-oracle bar. Decoding is
//! hostile-input hardened the same way [`decode_block`] is — every
//! claimed entry count is bounded by the remaining buffer *before* any
//! allocation trusts it.

use anyhow::{bail, Result};

use super::block::ModelBlock;
use super::topic_counts::TopicCounts;
use super::word_topic::SparseRow;

/// Append a LEB128 varint.
#[inline]
pub fn put_varint(buf: &mut Vec<u8>, mut x: u64) {
    loop {
        let byte = (x & 0x7f) as u8;
        x >>= 7;
        if x == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Read a LEB128 varint.
#[inline]
pub fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut x = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&byte) = buf.get(*pos) else {
            bail!("varint truncated at {pos}");
        };
        *pos += 1;
        x |= ((byte & 0x7f) as u64) << shift;
        if byte & 0x80 == 0 {
            return Ok(x);
        }
        shift += 7;
        if shift >= 64 {
            bail!("varint overflow");
        }
    }
}

#[inline]
fn zigzag(x: i64) -> u64 {
    ((x << 1) ^ (x >> 63)) as u64
}

#[inline]
fn unzigzag(x: u64) -> i64 {
    ((x >> 1) as i64) ^ -((x & 1) as i64)
}

/// Encode a model block.
pub fn encode_block(block: &ModelBlock) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16 + block.nnz() * 3);
    buf.extend_from_slice(&block.id.to_le_bytes());
    buf.extend_from_slice(&block.lo.to_le_bytes());
    buf.extend_from_slice(&block.hi.to_le_bytes());
    put_varint(&mut buf, block.stride as u64);
    put_varint(&mut buf, block.rows.len() as u64);
    for row in &block.rows {
        put_varint(&mut buf, row.nnz() as u64);
        let mut prev = 0u32;
        for (k, c) in row.iter() {
            put_varint(&mut buf, (k - prev) as u64);
            put_varint(&mut buf, c as u64);
            prev = k;
        }
    }
    buf
}

/// Bytes a LEB128 varint of `x` occupies.
#[inline]
fn varint_len(x: u64) -> u64 {
    (((64 - (x | 1).leading_zeros() as u64) + 6) / 7).max(1)
}

/// Length of [`encode_block`]'s output **without materializing it** — the
/// serving tier meters read-lease traffic per block copy, sometimes once
/// per token (a starved cache), so the O(block) encode allocation must
/// stay off that path.
pub fn encoded_block_len(block: &ModelBlock) -> u64 {
    let mut len = 12 + varint_len(block.stride as u64) + varint_len(block.rows.len() as u64);
    for row in &block.rows {
        len += varint_len(row.nnz() as u64);
        let mut prev = 0u32;
        for (k, c) in row.iter() {
            len += varint_len((k - prev) as u64) + varint_len(c as u64);
            prev = k;
        }
    }
    len
}

/// Decode a model block.
pub fn decode_block(buf: &[u8]) -> Result<ModelBlock> {
    if buf.len() < 12 {
        bail!("block header truncated");
    }
    let id = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    let lo = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let hi = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    let mut pos = 12;
    let stride = get_varint(buf, &mut pos)? as u32;
    if stride == 0 {
        bail!("zero stride");
    }
    let nrows = get_varint(buf, &mut pos)? as usize;
    let expect = ((hi - lo) as usize).div_ceil(stride as usize);
    if nrows != expect {
        bail!("row count {nrows} does not match range [{lo},{hi}) stride {stride}");
    }
    let mut rows = Vec::with_capacity(nrows);
    for _ in 0..nrows {
        let nnz = get_varint(buf, &mut pos)? as usize;
        // Every entry costs at least two bytes (two varints): bound the
        // claimed count by the remaining buffer before any allocation
        // trusts it — a hostile varint fits a 64 MiB frame but can claim
        // 2^64 entries.
        if nnz > (buf.len() - pos) / 2 {
            bail!("row claims {nnz} entries but only {} bytes remain", buf.len() - pos);
        }
        let mut entries = Vec::with_capacity(nnz);
        let mut prev = 0u32;
        for _ in 0..nnz {
            let dk = get_varint(buf, &mut pos)? as u32;
            let c = get_varint(buf, &mut pos)? as u32;
            let k = prev + dk;
            entries.push((k, c));
            prev = k;
        }
        rows.push(SparseRow::from_entries(entries));
    }
    if pos != buf.len() {
        bail!("trailing bytes after block");
    }
    Ok(ModelBlock { id, lo, hi, stride, rows, alias: Default::default() })
}

/// Encode a topic-totals vector (or signed delta).
pub fn encode_totals(t: &TopicCounts) -> Vec<u8> {
    let mut buf = Vec::with_capacity(4 + t.num_topics() * 2);
    put_varint(&mut buf, t.num_topics() as u64);
    for &c in t.as_slice() {
        put_varint(&mut buf, zigzag(c));
    }
    buf
}

/// Decode a topic-totals vector.
pub fn decode_totals(buf: &[u8]) -> Result<TopicCounts> {
    let mut pos = 0;
    let k = get_varint(buf, &mut pos)? as usize;
    let mut counts = Vec::with_capacity(k);
    for _ in 0..k {
        counts.push(unzigzag(get_varint(buf, &mut pos)?));
    }
    if pos != buf.len() {
        bail!("trailing bytes after totals");
    }
    Ok(TopicCounts::from_vec(counts))
}

/// Encode the sparse signed difference `new - base` between two totals
/// vectors of equal dimension. Entries ride as strictly increasing
/// index gaps with zigzag-varint deltas, so the cost is O(touched
/// buckets), not O(K).
pub fn encode_totals_delta(base: &TopicCounts, new: &TopicCounts) -> Vec<u8> {
    assert_eq!(
        base.num_topics(),
        new.num_topics(),
        "totals delta requires equal topic dimensions"
    );
    let mut buf = Vec::with_capacity(8);
    put_varint(&mut buf, base.num_topics() as u64);
    let mut n = 0u64;
    for (b, a) in base.as_slice().iter().zip(new.as_slice()) {
        if a != b {
            n += 1;
        }
    }
    put_varint(&mut buf, n);
    let mut prev = 0usize;
    for (k, (b, a)) in base.as_slice().iter().zip(new.as_slice()).enumerate() {
        if a != b {
            put_varint(&mut buf, (k - prev) as u64);
            put_varint(&mut buf, zigzag(a - b));
            prev = k;
        }
    }
    buf
}

/// Apply an [`encode_totals_delta`] payload in place. Typed errors on
/// dimension mismatch, out-of-range indices, non-increasing runs,
/// arithmetic overflow, or trailing bytes — never a panic (the peer
/// controls these bytes).
pub fn apply_totals_delta(t: &mut TopicCounts, buf: &[u8]) -> Result<()> {
    let mut pos = 0;
    let k = get_varint(buf, &mut pos)? as usize;
    if k != t.num_topics() {
        bail!("totals delta is over {k} topics, target has {}", t.num_topics());
    }
    let n = get_varint(buf, &mut pos)? as usize;
    // Each entry is at least two bytes (two varints): bound the claim
    // before trusting it.
    if n > (buf.len() - pos) / 2 {
        bail!("totals delta claims {n} entries but only {} bytes remain", buf.len() - pos);
    }
    let mut idx = 0usize;
    for i in 0..n {
        let gap = get_varint(buf, &mut pos)? as usize;
        if i > 0 && gap == 0 {
            bail!("totals delta indices are not strictly increasing");
        }
        idx = idx
            .checked_add(gap)
            .filter(|&x| x < k)
            .with_context(|| format!("totals delta index out of range (gap {gap})"))?;
        let d = unzigzag(get_varint(buf, &mut pos)?);
        let v = t
            .get(idx)
            .checked_add(d)
            .with_context(|| format!("totals delta overflows bucket {idx}"))?;
        t.set(idx, v);
    }
    if pos != buf.len() {
        bail!("trailing bytes after totals delta");
    }
    Ok(())
}

/// Encode the sparse difference between two blocks covering the same
/// `(id, lo, hi, stride)` word range: only rows that changed appear, and
/// within a changed row only the topics whose count changed, as signed
/// zigzag deltas over the merge-walk of the two sorted entry lists.
pub fn encode_block_delta(base: &ModelBlock, new: &ModelBlock) -> Vec<u8> {
    assert!(
        base.id == new.id
            && base.lo == new.lo
            && base.hi == new.hi
            && base.stride == new.stride
            && base.rows.len() == new.rows.len(),
        "block delta requires an identical word range"
    );
    let mut changed: Vec<(usize, Vec<(u32, i64)>)> = Vec::new();
    for (r, (b, a)) in base.rows.iter().zip(&new.rows).enumerate() {
        let diff = row_diff(b, a);
        if !diff.is_empty() {
            changed.push((r, diff));
        }
    }
    let mut buf = Vec::with_capacity(16 + changed.len() * 8);
    buf.extend_from_slice(&base.id.to_le_bytes());
    buf.extend_from_slice(&base.lo.to_le_bytes());
    buf.extend_from_slice(&base.hi.to_le_bytes());
    put_varint(&mut buf, base.stride as u64);
    put_varint(&mut buf, changed.len() as u64);
    let mut prev_row = 0usize;
    for (r, diff) in &changed {
        put_varint(&mut buf, (r - prev_row) as u64);
        prev_row = *r;
        put_varint(&mut buf, diff.len() as u64);
        let mut prev_k = 0u32;
        for &(k, d) in diff {
            put_varint(&mut buf, (k - prev_k) as u64);
            put_varint(&mut buf, zigzag(d));
            prev_k = k;
        }
    }
    buf
}

/// Signed sparse difference `new - base` of two topic-sorted rows.
fn row_diff(base: &SparseRow, new: &SparseRow) -> Vec<(u32, i64)> {
    let mut out = Vec::new();
    let (mut bi, mut ni) = (base.iter().peekable(), new.iter().peekable());
    loop {
        match (bi.peek().copied(), ni.peek().copied()) {
            (Some((bk, bc)), Some((nk, nc))) => {
                if bk == nk {
                    if bc != nc {
                        out.push((bk, nc as i64 - bc as i64));
                    }
                    bi.next();
                    ni.next();
                } else if bk < nk {
                    out.push((bk, -(bc as i64)));
                    bi.next();
                } else {
                    out.push((nk, nc as i64));
                    ni.next();
                }
            }
            (Some((bk, bc)), None) => {
                out.push((bk, -(bc as i64)));
                bi.next();
            }
            (None, Some((nk, nc))) => {
                out.push((nk, nc as i64));
                ni.next();
            }
            (None, None) => return out,
        }
    }
}

/// Apply an [`encode_block_delta`] payload in place. The header must
/// match the target block exactly (a delta never retargets); counts must
/// stay within `u32` and never go negative. Typed errors throughout,
/// entry counts bounded by the remaining buffer before allocation.
pub fn apply_block_delta(block: &mut ModelBlock, buf: &[u8]) -> Result<()> {
    if buf.len() < 12 {
        bail!("block delta header truncated");
    }
    let id = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    let lo = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let hi = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    let mut pos = 12;
    let stride = get_varint(buf, &mut pos)? as u32;
    if id != block.id || lo != block.lo || hi != block.hi || stride != block.stride {
        bail!(
            "block delta targets block {id} [{lo},{hi}) stride {stride}, \
             base is block {} [{},{}) stride {}",
            block.id,
            block.lo,
            block.hi,
            block.stride
        );
    }
    let nrows = get_varint(buf, &mut pos)? as usize;
    // A changed row costs at least three bytes (row gap + count + one
    // entry — empty diffs are never encoded).
    if nrows > (buf.len() - pos) / 3 {
        bail!("block delta claims {nrows} rows but only {} bytes remain", buf.len() - pos);
    }
    let mut row = 0usize;
    for i in 0..nrows {
        let gap = get_varint(buf, &mut pos)? as usize;
        if i > 0 && gap == 0 {
            bail!("block delta rows are not strictly increasing");
        }
        row = row
            .checked_add(gap)
            .filter(|&r| r < block.rows.len())
            .with_context(|| format!("block delta row out of range (gap {gap})"))?;
        let n = get_varint(buf, &mut pos)? as usize;
        if n == 0 {
            bail!("block delta encodes an empty row diff");
        }
        if n > (buf.len() - pos) / 2 {
            bail!("row diff claims {n} entries but only {} bytes remain", buf.len() - pos);
        }
        let mut diff = Vec::with_capacity(n);
        let mut prev = 0u32;
        for j in 0..n {
            let dk = get_varint(buf, &mut pos)? as u32;
            if j > 0 && dk == 0 {
                bail!("row diff topics are not strictly increasing");
            }
            let k = prev
                .checked_add(dk)
                .with_context(|| "row diff topic overflows u32")?;
            let d = unzigzag(get_varint(buf, &mut pos)?);
            diff.push((k, d));
            prev = k;
        }
        apply_row_diff(&mut block.rows[row], &diff)
            .with_context(|| format!("applying delta to row {row}"))?;
    }
    if pos != buf.len() {
        bail!("trailing bytes after block delta");
    }
    Ok(())
}

/// Merge a sorted signed diff into a sorted row; entries hitting zero
/// vanish (mirroring [`row_diff`]'s view of absence as count 0).
fn apply_row_diff(row: &mut SparseRow, diff: &[(u32, i64)]) -> Result<()> {
    let mut out = Vec::with_capacity(row.nnz() + diff.len());
    let mut di = diff.iter().peekable();
    for (k, c) in row.iter() {
        while let Some(&&(dk, dd)) = di.peek() {
            if dk >= k {
                break;
            }
            push_diffed(&mut out, dk, 0, dd)?;
            di.next();
        }
        if let Some(&&(dk, dd)) = di.peek() {
            if dk == k {
                push_diffed(&mut out, k, c as i64, dd)?;
                di.next();
                continue;
            }
        }
        out.push((k, c));
    }
    for &(dk, dd) in di {
        push_diffed(&mut out, dk, 0, dd)?;
    }
    *row = SparseRow::from_entries(out);
    Ok(())
}

fn push_diffed(out: &mut Vec<(u32, u32)>, k: u32, c: i64, d: i64) -> Result<()> {
    let v = c.checked_add(d).with_context(|| format!("count overflow at topic {k}"))?;
    if v < 0 || v > u32::MAX as i64 {
        bail!("delta drives topic {k} count to {v}, outside u32");
    }
    if v > 0 {
        out.push((k, v as u32));
    }
    Ok(())
}

/// Wire size of a block without materializing the encoding — used by the
/// memory/traffic accountant for the full-scale extrapolations where we
/// never build the 21.8M-word table.
pub fn block_wire_size_estimate(nnz: u64, num_rows: u64) -> u64 {
    // header 12 + nrows varint (≤5) + per-row nnz varint (≈1) +
    // per-entry ≈ 1.5 (topic delta) + 1.5 (count) bytes on Zipf data.
    12 + 5 + num_rows + nnz * 3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_block(seed: u64, lo: u32, hi: u32, k: u64) -> ModelBlock {
        let mut rng = Pcg64::new(seed);
        let mut b = ModelBlock::empty(3, lo, hi);
        for w in lo..hi {
            let n = rng.next_below(20);
            for _ in 0..n {
                b.row_mut(w).inc(rng.next_below(k) as u32);
            }
        }
        b
    }

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let values = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        for &v in &values {
            put_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &values {
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn varint_truncation_detected() {
        let mut buf = Vec::new();
        put_varint(&mut buf, u64::MAX);
        buf.pop();
        let mut pos = 0;
        assert!(get_varint(&buf, &mut pos).is_err());
    }

    #[test]
    fn block_roundtrip() {
        let b = random_block(10, 100, 164, 50);
        let enc = encode_block(&b);
        let dec = decode_block(&enc).unwrap();
        assert_eq!(dec, b);
    }

    #[test]
    fn empty_block_roundtrip() {
        let b = ModelBlock::empty(0, 5, 9);
        let dec = decode_block(&encode_block(&b)).unwrap();
        assert_eq!(dec, b);
    }

    #[test]
    fn encoded_len_matches_encoding_exactly() {
        for (seed, lo, hi, k) in [(10u64, 100u32, 164u32, 50u64), (7, 0, 1, 2), (3, 0, 40, 1000)]
        {
            let b = random_block(seed, lo, hi, k);
            assert_eq!(
                encoded_block_len(&b),
                encode_block(&b).len() as u64,
                "seed {seed}"
            );
        }
        let empty = ModelBlock::empty(0, 5, 9);
        assert_eq!(encoded_block_len(&empty), encode_block(&empty).len() as u64);
    }

    #[test]
    fn totals_roundtrip_including_negatives() {
        let t = TopicCounts::from_vec(vec![5, -3, 0, 1_000_000, -42]);
        let dec = decode_totals(&encode_totals(&t)).unwrap();
        assert_eq!(dec, t);
    }

    #[test]
    fn wire_size_tracks_sparsity_not_dimensions() {
        // Same range, different densities — size must scale with nnz.
        let sparse = random_block(1, 0, 256, 1000);
        let mut dense = ModelBlock::empty(0, 0, 256);
        let mut rng = Pcg64::new(2);
        for w in 0..256u32 {
            for _ in 0..200 {
                dense.row_mut(w).inc(rng.next_below(1000) as u32);
            }
        }
        let s = encode_block(&sparse).len();
        let d = encode_block(&dense).len();
        assert!(d > s * 3, "dense={d} sparse={s}");
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode_block(&[1, 2, 3]).is_err());
        let b = random_block(4, 0, 10, 20);
        let mut enc = encode_block(&b);
        enc.push(0); // trailing byte
        assert!(decode_block(&enc).is_err());
    }

    #[test]
    fn totals_delta_roundtrip_and_sparsity() {
        let base = TopicCounts::from_vec(vec![10, 0, 5, 7, 0, 3, 1_000_000]);
        let new = TopicCounts::from_vec(vec![10, 2, 5, 4, 0, 3, 999_999]);
        let enc = encode_totals_delta(&base, &new);
        // 3 touched buckets: far smaller than the 7-bucket full encoding
        // would be for realistic magnitudes, and exact on apply.
        let mut t = base.clone();
        apply_totals_delta(&mut t, &enc).unwrap();
        assert_eq!(t, new);
        // Identical vectors encode to a 2-varint header.
        let empty = encode_totals_delta(&base, &base);
        assert_eq!(empty.len(), 2);
        let mut t = base.clone();
        apply_totals_delta(&mut t, &empty).unwrap();
        assert_eq!(t, base);
    }

    #[test]
    fn totals_delta_rejects_garbage() {
        let base = TopicCounts::from_vec(vec![1, 2, 3]);
        let new = TopicCounts::from_vec(vec![3, 2, 1]);
        let enc = encode_totals_delta(&base, &new);
        // Truncations never panic.
        for cut in 0..enc.len() {
            let mut t = base.clone();
            assert!(apply_totals_delta(&mut t, &enc[..cut]).is_err(), "cut {cut}");
        }
        // Wrong dimension.
        let mut short = TopicCounts::from_vec(vec![1, 2]);
        assert!(apply_totals_delta(&mut short, &enc).is_err());
        // Hostile entry count: claims 2^40 entries in a few bytes.
        let mut buf = Vec::new();
        put_varint(&mut buf, 3);
        put_varint(&mut buf, 1 << 40);
        let mut t = base.clone();
        assert!(apply_totals_delta(&mut t, &buf).is_err());
        // Trailing byte.
        let mut tr = enc.clone();
        tr.push(0);
        let mut t = base;
        assert!(apply_totals_delta(&mut t, &tr).is_err());
    }

    #[test]
    fn block_delta_roundtrip_on_mutations() {
        let base = random_block(42, 0, 64, 50);
        let mut new = base.clone();
        // Mutations of every flavor: bump existing, insert fresh, remove.
        new.row_mut(3).inc(7);
        new.row_mut(10).inc(49);
        let first = base.row(20).iter().next();
        if let Some((k, _)) = first {
            new.row_mut(20).dec(k);
        }
        let enc = encode_block_delta(&base, &new);
        let mut b = base.clone();
        apply_block_delta(&mut b, &enc).unwrap();
        assert_eq!(b, new);
        // Unchanged block: header-only delta, applies to a no-op.
        let enc = encode_block_delta(&base, &base);
        assert_eq!(enc.len(), 14); // 12-byte header + stride + 0 rows
        let mut b = base.clone();
        apply_block_delta(&mut b, &enc).unwrap();
        assert_eq!(b, base);
        // Delta size tracks touched entries, not block size.
        let mut one = base.clone();
        one.row_mut(0).inc(1);
        assert!(encode_block_delta(&base, &one).len() < encode_block(&base).len() / 4);
    }

    #[test]
    fn block_delta_rejects_retarget_truncation_and_negatives() {
        let base = random_block(5, 0, 32, 40);
        let mut new = base.clone();
        new.row_mut(1).inc(3);
        let enc = encode_block_delta(&base, &new);
        for cut in 0..enc.len() {
            let mut b = base.clone();
            assert!(apply_block_delta(&mut b, &enc[..cut]).is_err(), "cut {cut}");
        }
        // Retargeting a different block is typed, not silent.
        let mut other = random_block(5, 0, 32, 40);
        other.id = 9;
        assert!(apply_block_delta(&mut other, &enc).is_err());
        // A delta that would drive a count negative is rejected.
        let mut buf = Vec::new();
        buf.extend_from_slice(&base.id.to_le_bytes());
        buf.extend_from_slice(&base.lo.to_le_bytes());
        buf.extend_from_slice(&base.hi.to_le_bytes());
        put_varint(&mut buf, base.stride as u64);
        put_varint(&mut buf, 1); // one row
        put_varint(&mut buf, 0); // row 0
        put_varint(&mut buf, 1); // one entry
        put_varint(&mut buf, 0); // topic 0
        put_varint(&mut buf, zigzag(-1_000_000));
        let mut b = base.clone();
        assert!(apply_block_delta(&mut b, &buf).is_err());
        // Hostile row count bounded before allocation.
        let mut buf = Vec::new();
        buf.extend_from_slice(&base.id.to_le_bytes());
        buf.extend_from_slice(&base.lo.to_le_bytes());
        buf.extend_from_slice(&base.hi.to_le_bytes());
        put_varint(&mut buf, base.stride as u64);
        put_varint(&mut buf, 1 << 50);
        let mut b = base;
        assert!(apply_block_delta(&mut b, &buf).is_err());
    }

    #[test]
    fn estimate_is_within_2x_of_actual() {
        let b = random_block(9, 0, 500, 200);
        let actual = encode_block(&b).len() as u64;
        let est = block_wire_size_estimate(b.nnz() as u64, b.num_words() as u64);
        assert!(est >= actual / 2 && est <= actual * 2, "actual={actual} est={est}");
    }
}
