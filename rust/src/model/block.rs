//! Model blocks: the unit of model-parallelism (§3.1).
//!
//! Two disjoint layouts of the vocabulary into `M` blocks:
//!
//! * **contiguous** — word-id ranges with balanced token mass (ids are
//!   frequency-ranked, so equal-width ranges would be wildly unbalanced);
//! * **strided** (default) — block `b` = words `{w : w ≡ b (mod M)}`.
//!   Every block then samples each frequency stratum, which uniformizes
//!   the per-(shard ∩ block) work cells and cuts round-barrier straggling
//!   (the §Perf ablation measures contiguous-vs-strided directly).
//!
//! A [`ModelBlock`] owns the sparse `C_t^k` rows for its word set
//! (`lo + i·stride`); exactly one holder may mutate it at any time, which
//! the KV-store lease protocol enforces.

use super::alias::AliasSlot;
use super::word_topic::SparseRow;

/// The static map from word ids to block ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockMap {
    layout: Layout,
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum Layout {
    /// Block `b` covers word ids `[bounds[b], bounds[b+1])`.
    Contiguous { bounds: Vec<u32> },
    /// Block `b` covers `{w : w % blocks == b}` over `[0, v)`.
    Strided { v: u32, blocks: u32 },
}

impl BlockMap {
    /// Strided layout over `v` words and `m` blocks.
    pub fn strided(v: usize, m: usize) -> BlockMap {
        assert!(m >= 1 && v >= m, "need v >= m >= 1 (v={v}, m={m})");
        BlockMap { layout: Layout::Strided { v: v as u32, blocks: m as u32 } }
    }

    /// Split `[0, V)` into `m` contiguous ranges with near-equal token
    /// mass given the per-word frequencies (ids must be frequency-ranked
    /// or at least the caller's true token counts).
    pub fn balanced(freqs: &[u64], m: usize) -> BlockMap {
        assert!(m >= 1, "need at least one block");
        let v = freqs.len();
        assert!(v >= m, "more blocks ({m}) than words ({v})");
        let total: u64 = freqs.iter().sum();
        let mut bounds = Vec::with_capacity(m + 1);
        bounds.push(0u32);
        let mut acc = 0u64;
        let mut next_target = 1u64;
        for (w, &f) in freqs.iter().enumerate() {
            acc += f;
            // Close block b when cumulative mass passes b/m of total, but
            // always leave enough words for the remaining blocks.
            let b = bounds.len() as u64;
            if b <= (m - 1) as u64 {
                let target = total * b / m as u64;
                let words_left = v - (w + 1);
                let blocks_left = m - bounds.len();
                if (acc >= target.max(next_target) && words_left >= blocks_left)
                    || words_left == blocks_left
                {
                    bounds.push((w + 1) as u32);
                    next_target = acc + 1;
                }
            }
        }
        while bounds.len() < m {
            // Degenerate tail (e.g. all mass in first words): split remaining
            // id space evenly.
            let last = *bounds.last().unwrap() as usize;
            let remaining = v - last;
            let blocks_left = m + 1 - bounds.len();
            bounds.push((last + remaining.div_ceil(blocks_left)) as u32);
        }
        bounds.push(v as u32);
        debug_assert_eq!(bounds.len(), m + 1);
        BlockMap { layout: Layout::Contiguous { bounds } }
    }

    /// Even contiguous split by word count (ablation baseline — no mass
    /// balancing).
    pub fn even(v: usize, m: usize) -> BlockMap {
        assert!(m >= 1 && v >= m);
        let bounds: Vec<u32> = (0..=m).map(|b| (v * b / m) as u32).collect();
        BlockMap { layout: Layout::Contiguous { bounds } }
    }

    pub fn num_blocks(&self) -> usize {
        match &self.layout {
            Layout::Contiguous { bounds } => bounds.len() - 1,
            Layout::Strided { blocks, .. } => *blocks as usize,
        }
    }

    /// Covering spec of block `b`: word ids `lo, lo+stride, …  < hi`.
    pub fn spec(&self, b: usize) -> (u32, u32, u32) {
        match &self.layout {
            Layout::Contiguous { bounds } => (bounds[b], bounds[b + 1], 1),
            Layout::Strided { v, blocks } => (b as u32, *v, *blocks),
        }
    }

    /// Word-id range `[lo, hi)` of block `b` (contiguous layouts only —
    /// callers needing layout-generality use [`BlockMap::spec`]).
    pub fn range(&self, b: usize) -> (u32, u32) {
        let (lo, hi, stride) = self.spec(b);
        assert_eq!(stride, 1, "range() on a strided block map");
        (lo, hi)
    }

    /// Which block a word id belongs to.
    pub fn block_of(&self, word: u32) -> usize {
        match &self.layout {
            Layout::Contiguous { bounds } => {
                debug_assert!(word < *bounds.last().unwrap());
                bounds.partition_point(|&b| b <= word) - 1
            }
            Layout::Strided { blocks, .. } => (word % blocks) as usize,
        }
    }

    /// Token mass of each block given frequencies.
    pub fn masses(&self, freqs: &[u64]) -> Vec<u64> {
        let mut masses = vec![0u64; self.num_blocks()];
        for (w, &f) in freqs.iter().enumerate() {
            masses[self.block_of(w as u32)] += f;
        }
        masses
    }

    /// Verify the blocks exactly cover `[0, v)` without overlap.
    pub fn is_exact_cover(&self, v: usize) -> bool {
        match &self.layout {
            Layout::Contiguous { bounds } => {
                bounds.first() == Some(&0)
                    && *bounds.last().unwrap() as usize == v
                    && bounds.windows(2).all(|w| w[0] < w[1])
            }
            Layout::Strided { v: sv, blocks } => *sv as usize == v && *blocks as usize <= v,
        }
    }
}

/// A block of the word–topic table: sparse rows for the word set
/// `{lo + i·stride | i < rows.len(), lo + i·stride < hi}` (stride 1 =
/// contiguous range).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelBlock {
    pub id: u32,
    /// First word id covered.
    pub lo: u32,
    /// Exclusive upper bound on word ids.
    pub hi: u32,
    /// Word-id step between consecutive rows.
    pub stride: u32,
    /// Rows indexed by `(word - lo) / stride`.
    pub rows: Vec<SparseRow>,
    /// Lease-scoped MH proposal-table cache ([`crate::model::alias`]):
    /// ignored by equality/serialization, cleared by the KV-store on
    /// commit, empty in clones.
    pub alias: AliasSlot,
}

impl ModelBlock {
    pub fn empty(id: u32, lo: u32, hi: u32) -> ModelBlock {
        Self::empty_strided(id, lo, hi, 1)
    }

    pub fn empty_strided(id: u32, lo: u32, hi: u32, stride: u32) -> ModelBlock {
        assert!(stride >= 1 && hi >= lo);
        let n = ((hi - lo) as usize).div_ceil(stride as usize);
        let rows = vec![SparseRow::new(); n];
        ModelBlock { id, lo, hi, stride, rows, alias: AliasSlot::default() }
    }

    pub fn num_words(&self) -> usize {
        self.rows.len()
    }

    /// Does this block own `word`'s row?
    #[inline]
    pub fn contains(&self, word: u32) -> bool {
        word >= self.lo && word < self.hi && (word - self.lo) % self.stride == 0
    }

    /// The `i`-th word id this block covers.
    #[inline]
    pub fn word_at(&self, i: usize) -> u32 {
        self.lo + i as u32 * self.stride
    }

    #[inline]
    pub fn row(&self, word: u32) -> &SparseRow {
        debug_assert!(self.contains(word), "word {word} outside block");
        &self.rows[((word - self.lo) / self.stride) as usize]
    }

    #[inline]
    pub fn row_mut(&mut self, word: u32) -> &mut SparseRow {
        debug_assert!(self.contains(word), "word {word} outside block");
        &mut self.rows[((word - self.lo) / self.stride) as usize]
    }

    /// Column sums over this block only.
    pub fn column_sums(&self, k: usize) -> Vec<i64> {
        let mut sums = vec![0i64; k];
        for row in &self.rows {
            for (t, c) in row.iter() {
                sums[t as usize] += c as i64;
            }
        }
        sums
    }

    /// Total non-zero entries (drives wire size).
    pub fn nnz(&self) -> usize {
        self.rows.iter().map(|r| r.nnz()).sum()
    }

    /// Approximate heap bytes (memory accounting). Excludes the alias
    /// cache, which is lease-scoped and accounted separately under
    /// `MemCategory::AliasCache` (see [`ModelBlock::alias_bytes`]).
    pub fn bytes(&self) -> u64 {
        self.rows.iter().map(|r| r.bytes()).sum::<u64>() + 16
    }

    /// Bytes of MH proposal tables cached on this block this lease.
    pub fn alias_bytes(&self) -> u64 {
        self.alias.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_covers_and_balances() {
        // Zipf-ish masses.
        let freqs: Vec<u64> = (1..=1000u64).map(|r| 10_000 / r).collect();
        for m in [1, 2, 4, 8, 32] {
            let map = BlockMap::balanced(&freqs, m);
            assert!(map.is_exact_cover(freqs.len()), "m={m}");
            assert_eq!(map.num_blocks(), m);
            let masses = map.masses(&freqs);
            let total: u64 = freqs.iter().sum();
            let max = *masses.iter().max().unwrap() as f64;
            // No block should exceed ~2.2x the fair share for this profile —
            // the head word alone caps achievable balance.
            assert!(
                max <= (total as f64 / m as f64) * 2.2 + freqs[0] as f64,
                "m={m} masses={masses:?}"
            );
        }
    }

    #[test]
    fn block_of_is_consistent_with_range() {
        let freqs = vec![5u64; 100];
        let map = BlockMap::balanced(&freqs, 7);
        for w in 0..100u32 {
            let b = map.block_of(w);
            let (lo, hi) = map.range(b);
            assert!(w >= lo && w < hi, "w={w} b={b} range=({lo},{hi})");
        }
    }

    #[test]
    fn even_split() {
        let map = BlockMap::even(10, 3);
        assert!(map.is_exact_cover(10));
        assert_eq!(map.range(0), (0, 3));
        assert_eq!(map.range(2), (6, 10));
    }

    #[test]
    fn degenerate_all_mass_in_head() {
        let mut freqs = vec![0u64; 50];
        freqs[0] = 1_000_000;
        let map = BlockMap::balanced(&freqs, 8);
        assert!(map.is_exact_cover(50));
        assert_eq!(map.num_blocks(), 8);
    }

    #[test]
    fn blocks_are_disjoint_word_sets() {
        let freqs: Vec<u64> = (1..=200u64).rev().collect();
        let map = BlockMap::balanced(&freqs, 5);
        let mut seen = vec![false; 200];
        for b in 0..map.num_blocks() {
            let (lo, hi) = map.range(b);
            for w in lo..hi {
                assert!(!seen[w as usize], "word {w} in two blocks");
                seen[w as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn model_block_rows() {
        let mut b = ModelBlock::empty(0, 10, 20);
        b.row_mut(15).inc(3);
        b.row_mut(15).inc(3);
        assert_eq!(b.row(15).get(3), 2);
        assert_eq!(b.nnz(), 1);
        let sums = b.column_sums(5);
        assert_eq!(sums[3], 2);
    }

    #[test]
    fn strided_map_covers_and_balances_zipf_mass() {
        // Zipf-like frequencies: strided blocks must be far better balanced
        // than contiguous-even and competitive with contiguous-balanced.
        let freqs: Vec<u64> = (1..=1000u64).map(|r| 100_000 / r).collect();
        let m = 8;
        let strided = BlockMap::strided(freqs.len(), m);
        assert!(strided.is_exact_cover(freqs.len()));
        assert_eq!(strided.num_blocks(), m);
        let masses = strided.masses(&freqs);
        let total: u64 = freqs.iter().sum();
        let max = *masses.iter().max().unwrap() as f64;
        let fair = total as f64 / m as f64;
        // The head word alone is ~17% of mass here; strided puts it in one
        // block but every other stratum is spread evenly.
        assert!(max < fair * 2.5, "masses={masses:?}");
        // Disjoint cover by construction:
        let mut seen = vec![false; freqs.len()];
        for b in 0..m {
            let (lo, hi, stride) = strided.spec(b);
            let mut w = lo;
            while w < hi {
                assert!(!seen[w as usize]);
                seen[w as usize] = true;
                assert_eq!(strided.block_of(w), b);
                w += stride;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn strided_model_block_indexing() {
        // Block 2 of 5 over V=23: words 2,7,12,17,22.
        let mut b = ModelBlock::empty_strided(2, 2, 23, 5);
        assert_eq!(b.num_words(), 5);
        assert_eq!(b.word_at(0), 2);
        assert_eq!(b.word_at(4), 22);
        assert!(b.contains(17));
        assert!(!b.contains(18));
        assert!(!b.contains(23));
        b.row_mut(17).inc(1);
        assert_eq!(b.row(17).get(1), 1);
        assert_eq!(b.column_sums(3)[1], 1);
    }

    #[test]
    fn range_panics_on_strided() {
        let map = BlockMap::strided(10, 2);
        let r = std::panic::catch_unwind(|| map.range(0));
        assert!(r.is_err());
    }
}
