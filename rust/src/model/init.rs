//! Initial state: random topic assignments and the counts they induce.
//!
//! Training state = `(Z, C_d^k, C_t^k, C_k)` where the three counts are pure
//! functions of `Z` and the corpus. Everything here is deterministic given
//! the seed, and [`Assignments::check_consistency`] re-derives the counts
//! from `Z` to validate any sampler or distributed protocol against
//! corruption — it is used liberally in integration tests.

use crate::corpus::Corpus;
use crate::util::rng::Pcg64;

use super::block::{BlockMap, ModelBlock};
use super::doc_topic::DocTopic;
use super::topic_counts::TopicCounts;
use super::word_topic::WordTopicTable;

/// Topic assignments `z_dn`, parallel to the corpus token streams.
#[derive(Debug, Clone)]
pub struct Assignments {
    pub z: Vec<Vec<u32>>,
    pub num_topics: usize,
}

impl Assignments {
    /// Uniform-random initialization.
    pub fn random(corpus: &Corpus, num_topics: usize, rng: &mut Pcg64) -> Assignments {
        let z = corpus
            .docs
            .iter()
            .map(|d| {
                d.tokens
                    .iter()
                    .map(|_| rng.next_below(num_topics as u64) as u32)
                    .collect()
            })
            .collect();
        Assignments { z, num_topics }
    }

    pub fn num_tokens(&self) -> usize {
        self.z.iter().map(|d| d.len()).sum()
    }

    /// Build the three count statistics from scratch.
    pub fn build_counts(&self, corpus: &Corpus) -> (DocTopic, WordTopicTable, TopicCounts) {
        let mut dt = DocTopic::zeros(corpus.num_docs());
        let mut wt = WordTopicTable::zeros(corpus.num_words(), self.num_topics);
        let mut ck = TopicCounts::zeros(self.num_topics);
        for (d, doc) in corpus.docs.iter().enumerate() {
            for (n, &w) in doc.tokens.iter().enumerate() {
                let k = self.z[d][n];
                dt.doc_mut(d).inc(k);
                wt.row_mut(w as usize).inc(k);
                ck.inc(k as usize);
            }
        }
        (dt, wt, ck)
    }

    /// Shard the word–topic table into model blocks per the block map.
    pub fn build_blocks(wt: &WordTopicTable, map: &BlockMap) -> Vec<ModelBlock> {
        (0..map.num_blocks())
            .map(|b| {
                let (lo, hi, stride) = map.spec(b);
                let mut block = ModelBlock::empty_strided(b as u32, lo, hi, stride);
                for (i, row) in block.rows.iter_mut().enumerate() {
                    *row = wt.rows[(lo + i as u32 * stride) as usize].clone();
                }
                block
            })
            .collect()
    }

    /// Verify `(dt, wt, ck)` equal the counts induced by `Z`. Returns a
    /// description of the first inconsistency found.
    pub fn check_consistency(
        &self,
        corpus: &Corpus,
        dt: &DocTopic,
        wt: &WordTopicTable,
        ck: &TopicCounts,
    ) -> Result<(), String> {
        let (edt, ewt, eck) = self.build_counts(corpus);
        for d in 0..corpus.num_docs() {
            if edt.doc(d) != dt.doc(d) {
                return Err(format!(
                    "doc-topic mismatch at doc {d}: expect {:?} got {:?}",
                    edt.doc(d),
                    dt.doc(d)
                ));
            }
        }
        for w in 0..corpus.num_words() {
            if ewt.row(w) != wt.row(w) {
                return Err(format!(
                    "word-topic mismatch at word {w}: expect {:?} got {:?}",
                    ewt.row(w),
                    wt.row(w)
                ));
            }
        }
        if eck != *ck {
            return Err(format!("topic totals mismatch: expect {eck:?} got {ck:?}"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, GenSpec};

    fn setup() -> (Corpus, Assignments) {
        let corpus = generate(&GenSpec {
            vocab: 200,
            docs: 100,
            avg_doc_len: 20,
            zipf_s: 1.05,
            topics: 5,
            alpha: 0.1,
            seed: 3,
        });
        let mut rng = Pcg64::new(77);
        let assign = Assignments::random(&corpus, 16, &mut rng);
        (corpus, assign)
    }

    #[test]
    fn counts_consistent_after_init() {
        let (corpus, assign) = setup();
        let (dt, wt, ck) = assign.build_counts(&corpus);
        assign.check_consistency(&corpus, &dt, &wt, &ck).unwrap();
        assert_eq!(ck.total() as usize, corpus.num_tokens());
        assert_eq!(wt.column_sums(), ck.as_slice().to_vec());
        for d in 0..corpus.num_docs() {
            assert_eq!(dt.doc(d).total() as usize, corpus.docs[d].len());
        }
    }

    #[test]
    fn blocks_partition_the_table() {
        let (corpus, assign) = setup();
        let (_, wt, ck) = assign.build_counts(&corpus);
        let map = BlockMap::balanced(&corpus.word_frequencies(), 4);
        let blocks = Assignments::build_blocks(&wt, &map);
        assert_eq!(blocks.len(), 4);
        // Sum of block column-sums equals global C_k.
        let mut sums = vec![0i64; 16];
        for b in &blocks {
            for (k, s) in b.column_sums(16).into_iter().enumerate() {
                sums[k] += s;
            }
        }
        assert_eq!(sums, ck.as_slice().to_vec());
        // Rows inside each block equal the table's rows.
        for b in &blocks {
            for (i, row) in b.rows.iter().enumerate() {
                let w = b.word_at(i);
                assert_eq!(row, wt.row(w as usize));
            }
        }
    }

    #[test]
    fn consistency_check_detects_corruption() {
        let (corpus, assign) = setup();
        let (dt, mut wt, ck) = assign.build_counts(&corpus);
        wt.row_mut(0).inc(7); // corrupt
        assert!(assign.check_consistency(&corpus, &dt, &wt, &ck).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let (corpus, _) = setup();
        let mut r1 = Pcg64::new(5);
        let mut r2 = Pcg64::new(5);
        let a = Assignments::random(&corpus, 8, &mut r1);
        let b = Assignments::random(&corpus, 8, &mut r2);
        assert_eq!(a.z, b.z);
    }
}
