//! Word–topic counts `C_t^k` — the big model.
//!
//! Rows are stored sparse ([`SparseRow`]: sorted by topic id for
//! deterministic serialization and O(K_t) merges); the whole-table type
//! [`WordTopicTable`] exists for single-process samplers and tests, while
//! distributed training shards rows into [`super::block::ModelBlock`]s that
//! live in the KV-store and never coexist fully on one node.

/// One sparse word–topic row: `(topic, count)` sorted ascending by topic.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SparseRow {
    entries: Vec<(u32, u32)>,
}

impl SparseRow {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_entries(mut entries: Vec<(u32, u32)>) -> Self {
        entries.retain(|&(_, c)| c > 0);
        entries.sort_unstable_by_key(|&(k, _)| k);
        SparseRow { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// `K_t`: non-zero topics in this row.
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.entries.iter().copied()
    }

    pub fn get(&self, topic: u32) -> u32 {
        match self.entries.binary_search_by_key(&topic, |&(k, _)| k) {
            Ok(i) => self.entries[i].1,
            Err(_) => 0,
        }
    }

    pub fn inc(&mut self, topic: u32) {
        match self.entries.binary_search_by_key(&topic, |&(k, _)| k) {
            Ok(i) => self.entries[i].1 += 1,
            Err(i) => self.entries.insert(i, (topic, 1)),
        }
    }

    pub fn dec(&mut self, topic: u32) {
        match self.entries.binary_search_by_key(&topic, |&(k, _)| k) {
            Ok(i) => {
                self.entries[i].1 -= 1;
                if self.entries[i].1 == 0 {
                    self.entries.remove(i);
                }
            }
            Err(_) => panic!("dec of absent topic {topic} in word row"),
        }
    }

    /// Write this row into a dense scratch slice (len K), returning the
    /// topics touched so the caller can clear them cheaply afterwards.
    pub fn expand_into(&self, dense: &mut [u32], touched: &mut Vec<u32>) {
        for &(k, c) in &self.entries {
            dense[k as usize] = c;
            touched.push(k);
        }
    }

    /// Rebuild from a dense scratch slice given the touched topic list.
    pub fn compress_from(dense: &[u32], touched: &[u32]) -> SparseRow {
        let mut entries: Vec<(u32, u32)> = touched
            .iter()
            .filter_map(|&k| {
                let c = dense[k as usize];
                (c > 0).then_some((k, c))
            })
            .collect();
        entries.sort_unstable_by_key(|&(k, _)| k);
        entries.dedup_by_key(|e| e.0);
        SparseRow { entries }
    }

    pub fn total(&self) -> u64 {
        self.entries.iter().map(|&(_, c)| c as u64).sum()
    }

    /// Approximate heap bytes (memory accounting). Length-based, not
    /// capacity-based: byte accounting must be a pure function of row
    /// *content* so that a block which took a detour through the disk
    /// tier (whose codec normalizes capacity to nnz) accounts identically
    /// to one that stayed resident — budget decisions built on these
    /// bytes (pipeline staging, spill eviction) feed the bitwise
    /// determinism bar.
    pub fn bytes(&self) -> u64 {
        (self.entries.len() * 8 + 24) as u64
    }
}

/// Full `V × K` table (single-process use: oracle sampler, tests, the
/// Yahoo!LDA baseline's per-worker replica).
#[derive(Debug, Clone, Default)]
pub struct WordTopicTable {
    pub rows: Vec<SparseRow>,
    num_topics: usize,
}

impl WordTopicTable {
    pub fn zeros(num_words: usize, num_topics: usize) -> Self {
        WordTopicTable { rows: vec![SparseRow::new(); num_words], num_topics }
    }

    pub fn num_words(&self) -> usize {
        self.rows.len()
    }

    pub fn num_topics(&self) -> usize {
        self.num_topics
    }

    #[inline]
    pub fn row(&self, w: usize) -> &SparseRow {
        &self.rows[w]
    }

    #[inline]
    pub fn row_mut(&mut self, w: usize) -> &mut SparseRow {
        &mut self.rows[w]
    }

    /// Column sums = `C_k` recomputed from scratch (consistency checks).
    pub fn column_sums(&self) -> Vec<i64> {
        let mut sums = vec![0i64; self.num_topics];
        for row in &self.rows {
            for (k, c) in row.iter() {
                sums[k as usize] += c as i64;
            }
        }
        sums
    }

    /// Mean `K_t` over non-empty rows.
    pub fn avg_kt(&self) -> f64 {
        let nonempty: Vec<usize> = self.rows.iter().map(|r| r.nnz()).filter(|&n| n > 0).collect();
        if nonempty.is_empty() {
            return 0.0;
        }
        nonempty.iter().sum::<usize>() as f64 / nonempty.len() as f64
    }

    pub fn bytes(&self) -> u64 {
        self.rows.iter().map(|r| r.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn row_ops() {
        let mut r = SparseRow::new();
        r.inc(7);
        r.inc(7);
        r.inc(1);
        assert_eq!(r.get(7), 2);
        assert_eq!(r.get(1), 1);
        assert_eq!(r.get(2), 0);
        assert_eq!(r.nnz(), 2);
        r.dec(7);
        r.dec(7);
        assert_eq!(r.nnz(), 1);
        assert_eq!(r.total(), 1);
    }

    #[test]
    fn expand_compress_roundtrip() {
        let mut rng = Pcg64::new(2);
        let k = 64;
        let mut row = SparseRow::new();
        for _ in 0..200 {
            row.inc(rng.next_below(k as u64) as u32);
        }
        let mut dense = vec![0u32; k];
        let mut touched = Vec::new();
        row.expand_into(&mut dense, &mut touched);
        let back = SparseRow::compress_from(&dense, &touched);
        assert_eq!(back, row);
        // clear
        for &t in &touched {
            dense[t as usize] = 0;
        }
        assert!(dense.iter().all(|&x| x == 0));
    }

    #[test]
    fn entries_sorted_by_topic() {
        let r = SparseRow::from_entries(vec![(9, 1), (2, 3), (5, 0), (4, 2)]);
        let ks: Vec<u32> = r.iter().map(|(k, _)| k).collect();
        assert_eq!(ks, vec![2, 4, 9]); // zero-count dropped, sorted
    }

    #[test]
    fn table_column_sums() {
        let mut t = WordTopicTable::zeros(3, 4);
        t.row_mut(0).inc(0);
        t.row_mut(1).inc(0);
        t.row_mut(2).inc(3);
        assert_eq!(t.column_sums(), vec![2, 0, 0, 1]);
    }

    #[test]
    fn avg_kt_ignores_empty_rows() {
        let mut t = WordTopicTable::zeros(4, 8);
        t.row_mut(0).inc(1);
        t.row_mut(0).inc(2);
        t.row_mut(1).inc(3);
        assert!((t.avg_kt() - 1.5).abs() < 1e-12);
    }
}
