//! Training-state checkpointing.
//!
//! A checkpoint stores the topic assignments `Z` (the sufficient state —
//! all three count statistics are pure functions of `Z` and the corpus)
//! plus a corpus fingerprint and the topic count, varint-packed with the
//! same codec as the wire format. Restoring rebuilds the counts and
//! verifies the fingerprint, so resuming against the wrong corpus fails
//! loudly instead of silently corrupting counts.
//!
//! Format:
//! ```text
//! magic "MPLDAKPT" | version:varint | num_topics:varint |
//! corpus_fp:u64 | num_docs:varint | (doc_len:varint z:varint*)*
//! ```

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::corpus::Corpus;

use super::init::Assignments;
use super::wire::{get_varint, put_varint};

const MAGIC: &[u8; 8] = b"MPLDAKPT";
const VERSION: u64 = 1;

/// Order-sensitive corpus fingerprint (FNV-1a over doc lengths and token
/// ids): cheap, stable across runs, catches preset/seed/path mismatches.
pub fn corpus_fingerprint(corpus: &Corpus) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x100000001b3);
    };
    mix(corpus.num_docs() as u64);
    mix(corpus.num_words() as u64);
    for d in &corpus.docs {
        mix(d.tokens.len() as u64);
        for &t in &d.tokens {
            mix(t as u64);
        }
    }
    h
}

/// Serialize assignments to a writer.
pub fn write_checkpoint<W: Write>(
    mut w: W,
    assign: &Assignments,
    corpus: &Corpus,
) -> Result<()> {
    let mut buf = Vec::with_capacity(assign.num_tokens() * 2 + 64);
    buf.extend_from_slice(MAGIC);
    put_varint(&mut buf, VERSION);
    put_varint(&mut buf, assign.num_topics as u64);
    buf.extend_from_slice(&corpus_fingerprint(corpus).to_le_bytes());
    put_varint(&mut buf, assign.z.len() as u64);
    for doc in &assign.z {
        put_varint(&mut buf, doc.len() as u64);
        for &z in doc {
            put_varint(&mut buf, z as u64);
        }
    }
    w.write_all(&buf).context("writing checkpoint")
}

/// Deserialize assignments, verifying the corpus fingerprint.
pub fn read_checkpoint<R: Read>(mut r: R, corpus: &Corpus) -> Result<Assignments> {
    let mut buf = Vec::new();
    r.read_to_end(&mut buf).context("reading checkpoint")?;
    if buf.len() < 16 || &buf[..8] != MAGIC {
        bail!("not a mplda checkpoint (bad magic)");
    }
    let mut pos = 8;
    let version = get_varint(&buf, &mut pos)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let num_topics = get_varint(&buf, &mut pos)? as usize;
    let fp = u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap());
    pos += 8;
    let expect = corpus_fingerprint(corpus);
    if fp != expect {
        bail!("checkpoint was written for a different corpus (fp {fp:#x} != {expect:#x})");
    }
    let num_docs = get_varint(&buf, &mut pos)? as usize;
    if num_docs != corpus.num_docs() {
        bail!("doc count mismatch: checkpoint {num_docs}, corpus {}", corpus.num_docs());
    }
    let mut z = Vec::with_capacity(num_docs);
    for d in 0..num_docs {
        let len = get_varint(&buf, &mut pos)? as usize;
        if len != corpus.docs[d].tokens.len() {
            bail!("doc {d} length mismatch");
        }
        let mut doc = Vec::with_capacity(len);
        for _ in 0..len {
            let zi = get_varint(&buf, &mut pos)? as u32;
            if zi as usize >= num_topics {
                bail!("topic id {zi} out of range (K={num_topics})");
            }
            doc.push(zi);
        }
        z.push(doc);
    }
    if pos != buf.len() {
        bail!("trailing bytes in checkpoint");
    }
    Ok(Assignments { z, num_topics })
}

/// Convenience: save to a path.
pub fn save<P: AsRef<Path>>(path: P, assign: &Assignments, corpus: &Corpus) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    write_checkpoint(std::io::BufWriter::new(f), assign, corpus)
}

/// Convenience: load from a path.
pub fn load<P: AsRef<Path>>(path: P, corpus: &Corpus) -> Result<Assignments> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    read_checkpoint(std::io::BufReader::new(f), corpus)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, GenSpec};
    use crate::util::rng::Pcg64;

    fn fixture() -> (Corpus, Assignments) {
        let corpus = generate(&GenSpec {
            vocab: 100,
            docs: 50,
            avg_doc_len: 15,
            zipf_s: 1.05,
            topics: 4,
            alpha: 0.1,
            seed: 77,
        });
        let mut rng = Pcg64::new(1);
        let assign = Assignments::random(&corpus, 12, &mut rng);
        (corpus, assign)
    }

    #[test]
    fn round_trip_preserves_state() {
        let (corpus, assign) = fixture();
        let mut buf = Vec::new();
        write_checkpoint(&mut buf, &assign, &corpus).unwrap();
        let loaded = read_checkpoint(&buf[..], &corpus).unwrap();
        assert_eq!(loaded.z, assign.z);
        assert_eq!(loaded.num_topics, 12);
        // Counts rebuilt from the restored Z match the originals.
        let (dt, wt, ck) = assign.build_counts(&corpus);
        loaded.check_consistency(&corpus, &dt, &wt, &ck).unwrap();
    }

    #[test]
    fn wrong_corpus_rejected() {
        let (corpus, assign) = fixture();
        let mut buf = Vec::new();
        write_checkpoint(&mut buf, &assign, &corpus).unwrap();
        let other = generate(&GenSpec {
            vocab: 100,
            docs: 50,
            avg_doc_len: 15,
            zipf_s: 1.05,
            topics: 4,
            alpha: 0.1,
            seed: 78, // different corpus
        });
        let err = read_checkpoint(&buf[..], &other).unwrap_err().to_string();
        assert!(err.contains("different corpus"), "{err}");
    }

    #[test]
    fn garbage_rejected() {
        let (corpus, _) = fixture();
        assert!(read_checkpoint(&b"nonsense"[..], &corpus).is_err());
        let mut bad = MAGIC.to_vec();
        bad.push(99); // version 99
        assert!(read_checkpoint(&bad[..], &corpus).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let (corpus, assign) = fixture();
        let mut buf = Vec::new();
        write_checkpoint(&mut buf, &assign, &corpus).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read_checkpoint(&buf[..], &corpus).is_err());
    }

    #[test]
    fn file_round_trip() {
        let (corpus, assign) = fixture();
        let path = std::env::temp_dir().join(format!("mplda_ckpt_{}.bin", std::process::id()));
        save(&path, &assign, &corpus).unwrap();
        let loaded = load(&path, &corpus).unwrap();
        assert_eq!(loaded.z, assign.z);
        std::fs::remove_file(&path).ok();
    }
}
