//! Training-state checkpointing.
//!
//! A **v1** checkpoint stores the topic assignments `Z` (the sufficient
//! state — all three count statistics are pure functions of `Z` and the
//! corpus) plus a corpus fingerprint and the topic count, varint-packed
//! with the same codec as the wire format. Restoring rebuilds the counts
//! and verifies the fingerprint, so resuming against the wrong corpus
//! fails loudly instead of silently corrupting counts.
//!
//! A **v2** checkpoint (written by `Session::checkpoint` /
//! [`write_resumable`]) appends a [`ResumeState`] trailer: the completed
//! iteration count, every worker's raw RNG stream position, and the
//! doc–topic counts **in their live storage order**. The trailer is what
//! makes resume *bitwise*-deterministic rather than merely statistically
//! equivalent: the samplers' bucket walks and floating-point summations
//! depend on the [`SparseCounts`](super::SparseCounts) entry order, and
//! the RNG streams must continue from their exact positions, so a resumed
//! run reproduces the uninterrupted run's log-likelihood series and
//! `model_digest` exactly (asserted by `rust/tests/session_resume.rs`).
//!
//! Format:
//! ```text
//! magic "MPLDAKPT" | version:varint | num_topics:varint |
//! corpus_fp:u64 | num_docs:varint | (doc_len:varint z:varint*)*
//! -- v2 trailer --
//! iteration:varint | num_workers:varint | (rng state:16B inc:16B)* |
//! (K_d:varint (topic:varint count:varint)*)*   # per doc, live order
//! ```

//!
//! Periodic **async snapshots** ([`AsyncCheckpointer`]) keep serialization
//! off the sampling path: the driver hands a cloned `(Z, ResumeState)`
//! snapshot to a background thread, which encodes and writes it to
//! `<dir>/ckpt-<iteration>.mplda` via write-to-temp + atomic rename. A
//! reader scanning with [`find_latest_checkpoint`] therefore never
//! observes a partially-written file: the final name only ever appears
//! complete.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use crate::corpus::Corpus;

use super::doc_topic::{DocTopic, SparseCounts};
use super::init::Assignments;
use super::wire::{get_varint, put_varint};

const MAGIC: &[u8; 8] = b"MPLDAKPT";
const VERSION_PLAIN: u64 = 1;
const VERSION_RESUMABLE: u64 = 2;

/// The mid-run trainer state a v2 checkpoint carries beyond `Z` — see the
/// module docs for why each piece is needed for bitwise-exact resume.
#[derive(Debug, Clone)]
pub struct ResumeState {
    /// Completed iterations at checkpoint time.
    pub iteration: usize,
    /// Raw `(state, inc)` of each worker's RNG stream, in worker order.
    pub worker_rng: Vec<(u128, u128)>,
    /// Doc–topic counts with live entry order preserved.
    pub dt: DocTopic,
}

/// Order-sensitive corpus fingerprint (FNV-1a over doc lengths and token
/// ids): cheap, stable across runs, catches preset/seed/path mismatches.
pub fn corpus_fingerprint(corpus: &Corpus) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x100000001b3);
    };
    mix(corpus.num_docs() as u64);
    mix(corpus.num_words() as u64);
    for d in &corpus.docs {
        mix(d.tokens.len() as u64);
        for &t in &d.tokens {
            mix(t as u64);
        }
    }
    h
}

fn encode_header(buf: &mut Vec<u8>, version: u64, assign: &Assignments, fingerprint: u64) {
    buf.extend_from_slice(MAGIC);
    put_varint(buf, version);
    put_varint(buf, assign.num_topics as u64);
    buf.extend_from_slice(&fingerprint.to_le_bytes());
    put_varint(buf, assign.z.len() as u64);
    for doc in &assign.z {
        put_varint(buf, doc.len() as u64);
        for &z in doc {
            put_varint(buf, z as u64);
        }
    }
}

/// Serialize assignments to a writer (v1: no resume trailer).
pub fn write_checkpoint<W: Write>(
    mut w: W,
    assign: &Assignments,
    corpus: &Corpus,
) -> Result<()> {
    let mut buf = Vec::with_capacity(assign.num_tokens() * 2 + 64);
    encode_header(&mut buf, VERSION_PLAIN, assign, corpus_fingerprint(corpus));
    w.write_all(&buf).context("writing checkpoint")
}

/// Serialize assignments plus the [`ResumeState`] trailer (v2).
pub fn write_resumable<W: Write>(
    w: W,
    assign: &Assignments,
    corpus: &Corpus,
    state: &ResumeState,
) -> Result<()> {
    write_resumable_with_fingerprint(w, assign, corpus_fingerprint(corpus), state)
}

/// [`write_resumable`] with a precomputed corpus fingerprint — what the
/// [`AsyncCheckpointer`]'s writer thread uses, so snapshot jobs never
/// need to carry (or re-hash) the corpus itself.
pub fn write_resumable_with_fingerprint<W: Write>(
    mut w: W,
    assign: &Assignments,
    fingerprint: u64,
    state: &ResumeState,
) -> Result<()> {
    if state.dt.num_docs() != assign.z.len() {
        bail!(
            "resume state covers {} docs, assignments cover {}",
            state.dt.num_docs(),
            assign.z.len()
        );
    }
    let mut buf = Vec::with_capacity(assign.num_tokens() * 4 + 64);
    encode_header(&mut buf, VERSION_RESUMABLE, assign, fingerprint);
    put_varint(&mut buf, state.iteration as u64);
    put_varint(&mut buf, state.worker_rng.len() as u64);
    for &(s, inc) in &state.worker_rng {
        buf.extend_from_slice(&s.to_le_bytes());
        buf.extend_from_slice(&inc.to_le_bytes());
    }
    for d in 0..state.dt.num_docs() {
        let counts = state.dt.doc(d);
        put_varint(&mut buf, counts.len() as u64);
        for (k, c) in counts.iter() {
            put_varint(&mut buf, k as u64);
            put_varint(&mut buf, c as u64);
        }
    }
    w.write_all(&buf).context("writing resumable checkpoint")
}

fn get_u128(buf: &[u8], pos: &mut usize) -> Result<u128> {
    if buf.len() < *pos + 16 {
        bail!("truncated checkpoint (u128 field)");
    }
    let v = u128::from_le_bytes(buf[*pos..*pos + 16].try_into().unwrap());
    *pos += 16;
    Ok(v)
}

/// Deserialize assignments, verifying the corpus fingerprint. Accepts
/// both versions; any v2 resume trailer is validated and discarded.
pub fn read_checkpoint<R: Read>(r: R, corpus: &Corpus) -> Result<Assignments> {
    read_resumable(r, corpus).map(|(assign, _)| assign)
}

/// Deserialize assignments and, for v2 checkpoints, the resume trailer.
/// The trailer's doc–topic counts are verified against the counts `Z`
/// induces, so a corrupted checkpoint fails here rather than training on
/// inconsistent state.
pub fn read_resumable<R: Read>(
    mut r: R,
    corpus: &Corpus,
) -> Result<(Assignments, Option<ResumeState>)> {
    let mut buf = Vec::new();
    r.read_to_end(&mut buf).context("reading checkpoint")?;
    if buf.len() < 16 || &buf[..8] != MAGIC {
        bail!("not a mplda checkpoint (bad magic)");
    }
    let mut pos = 8;
    let version = get_varint(&buf, &mut pos)?;
    if version != VERSION_PLAIN && version != VERSION_RESUMABLE {
        bail!("unsupported checkpoint version {version}");
    }
    let num_topics = get_varint(&buf, &mut pos)? as usize;
    if num_topics == 0 || num_topics > 1 << 26 {
        bail!("implausible topic count {num_topics} in checkpoint");
    }
    let fp = u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap());
    pos += 8;
    let expect = corpus_fingerprint(corpus);
    if fp != expect {
        bail!("checkpoint was written for a different corpus (fp {fp:#x} != {expect:#x})");
    }
    let num_docs = get_varint(&buf, &mut pos)? as usize;
    if num_docs != corpus.num_docs() {
        bail!("doc count mismatch: checkpoint {num_docs}, corpus {}", corpus.num_docs());
    }
    let mut z = Vec::with_capacity(num_docs);
    for d in 0..num_docs {
        let len = get_varint(&buf, &mut pos)? as usize;
        if len != corpus.docs[d].tokens.len() {
            bail!("doc {d} length mismatch");
        }
        let mut doc = Vec::with_capacity(len);
        for _ in 0..len {
            let zi = get_varint(&buf, &mut pos)? as u32;
            if zi as usize >= num_topics {
                bail!("topic id {zi} out of range (K={num_topics})");
            }
            doc.push(zi);
        }
        z.push(doc);
    }
    let assign = Assignments { z, num_topics };

    let state = if version == VERSION_RESUMABLE {
        let iteration = get_varint(&buf, &mut pos)? as usize;
        let num_workers = get_varint(&buf, &mut pos)? as usize;
        if num_workers == 0 || num_workers > 1 << 20 {
            bail!("implausible worker count {num_workers} in checkpoint");
        }
        let mut worker_rng = Vec::with_capacity(num_workers);
        for _ in 0..num_workers {
            let s = get_u128(&buf, &mut pos)?;
            let inc = get_u128(&buf, &mut pos)?;
            worker_rng.push((s, inc));
        }
        let mut dt = DocTopic::zeros(num_docs);
        for d in 0..num_docs {
            let kd = get_varint(&buf, &mut pos)? as usize;
            if kd > num_topics {
                bail!("doc {d}: K_d {kd} exceeds K={num_topics} — corrupt checkpoint");
            }
            let mut entries = Vec::with_capacity(kd);
            let mut prev_count = u32::MAX;
            for _ in 0..kd {
                let k = get_varint(&buf, &mut pos)? as u32;
                let c = get_varint(&buf, &mut pos)? as u32;
                if k as usize >= num_topics {
                    bail!("doc {d}: topic {k} out of range (K={num_topics})");
                }
                if c == 0 || c > prev_count {
                    bail!("doc {d}: doc-topic entries must be positive and descending");
                }
                if entries.iter().any(|&(kk, _)| kk == k) {
                    bail!("doc {d}: duplicate topic {k} in doc-topic counts");
                }
                prev_count = c;
                entries.push((k, c));
            }
            *dt.doc_mut(d) = SparseCounts::from_ordered_entries(entries);
        }
        // The trailer must agree with the counts Z induces (the trailer
        // only adds *order*, never different values). Tallied per doc
        // with one reusable dense scratch — no full-table rebuild; the
        // driver rebuilds the model counts once, after this returns.
        let mut scratch = vec![0u32; num_topics];
        for d in 0..num_docs {
            let mut nonzero = 0usize;
            for &z in &assign.z[d] {
                if scratch[z as usize] == 0 {
                    nonzero += 1;
                }
                scratch[z as usize] += 1;
            }
            let doc = dt.doc(d);
            // Duplicate topics were rejected while parsing, so equal
            // entry counts + per-entry equality ⇒ exact map equality.
            let ok = doc.len() == nonzero
                && doc.iter().all(|(k, c)| scratch[k as usize] == c);
            for &z in &assign.z[d] {
                scratch[z as usize] = 0;
            }
            if !ok {
                bail!("doc {d}: doc-topic counts disagree with assignments");
            }
        }
        Some(ResumeState { iteration, worker_rng, dt })
    } else {
        None
    };

    if pos != buf.len() {
        bail!("trailing bytes in checkpoint");
    }
    Ok((assign, state))
}

/// Convenience: save to a path (v1).
pub fn save<P: AsRef<Path>>(path: P, assign: &Assignments, corpus: &Corpus) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    write_checkpoint(std::io::BufWriter::new(f), assign, corpus)
}

/// Convenience: load from a path (either version; trailer discarded).
pub fn load<P: AsRef<Path>>(path: P, corpus: &Corpus) -> Result<Assignments> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    read_checkpoint(std::io::BufReader::new(f), corpus)
}

/// Convenience: save a resumable (v2) checkpoint to a path.
pub fn save_resumable<P: AsRef<Path>>(
    path: P,
    assign: &Assignments,
    corpus: &Corpus,
    state: &ResumeState,
) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    write_resumable(std::io::BufWriter::new(f), assign, corpus, state)
}

/// Convenience: load either version from a path, keeping the trailer.
pub fn load_resumable<P: AsRef<Path>>(
    path: P,
    corpus: &Corpus,
) -> Result<(Assignments, Option<ResumeState>)> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    read_resumable(std::io::BufReader::new(f), corpus)
}

/// File name of a periodic snapshot for `iteration`.
fn snapshot_name(iteration: usize) -> String {
    format!("ckpt-{iteration}.mplda")
}

/// Scan `dir` for completed periodic snapshots (`ckpt-<iteration>.mplda`)
/// and return the newest as `(iteration, path)`. In-flight `*.tmp` files
/// are never candidates — the atomic rename in the writer thread means a
/// final-named file is always complete. `Ok(None)` when the directory has
/// no snapshots (or does not exist yet).
pub fn find_latest_checkpoint<P: AsRef<Path>>(dir: P) -> Result<Option<(usize, PathBuf)>> {
    let dir = dir.as_ref();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("scanning {dir:?}")),
    };
    let mut best: Option<(usize, PathBuf)> = None;
    for entry in entries {
        let entry = entry.with_context(|| format!("scanning {dir:?}"))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(iter) = name
            .strip_prefix("ckpt-")
            .and_then(|rest| rest.strip_suffix(".mplda"))
            .and_then(|n| n.parse::<usize>().ok())
        else {
            continue;
        };
        let newer = match &best {
            Some((b, _)) => iter > *b,
            None => true,
        };
        if newer {
            best = Some((iter, entry.path()));
        }
    }
    Ok(best)
}

/// One queued snapshot: everything the writer thread needs, owned.
struct SnapshotJob {
    iteration: usize,
    fingerprint: u64,
    assign: Assignments,
    state: ResumeState,
}

/// Background checkpoint writer: snapshots queue through a channel and
/// are encoded + written on a dedicated thread, so the only cost on the
/// sampling path is cloning the state to snapshot. Each snapshot lands as
/// `<dir>/ckpt-<iteration>.mplda`, written to a `.tmp` sibling first and
/// atomically renamed — a crash mid-write leaves a stale `.tmp` that
/// [`find_latest_checkpoint`] ignores, never a corrupt "latest".
pub struct AsyncCheckpointer {
    dir: PathBuf,
    tx: Option<mpsc::Sender<SnapshotJob>>,
    writer: Option<JoinHandle<Result<()>>>,
}

impl AsyncCheckpointer {
    /// Spawn the writer thread targeting `dir` (created if missing).
    pub fn new<P: AsRef<Path>>(dir: P) -> Result<AsyncCheckpointer> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
        let (tx, rx) = mpsc::channel::<SnapshotJob>();
        let writer_dir = dir.clone();
        let writer = std::thread::spawn(move || -> Result<()> {
            for job in rx {
                let tmp = writer_dir.join(format!("{}.tmp", snapshot_name(job.iteration)));
                let done = writer_dir.join(snapshot_name(job.iteration));
                let f = std::fs::File::create(&tmp)
                    .with_context(|| format!("creating {tmp:?}"))?;
                write_resumable_with_fingerprint(
                    std::io::BufWriter::new(f),
                    &job.assign,
                    job.fingerprint,
                    &job.state,
                )?;
                std::fs::rename(&tmp, &done)
                    .with_context(|| format!("publishing {done:?}"))?;
            }
            Ok(())
        });
        Ok(AsyncCheckpointer { dir, tx: Some(tx), writer: Some(writer) })
    }

    /// The snapshot directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Queue a snapshot. Returns immediately; serialization and I/O run
    /// on the writer thread. Errors only if the writer already exited
    /// (its failure surfaces in [`AsyncCheckpointer::finish`]).
    pub fn submit(
        &self,
        iteration: usize,
        fingerprint: u64,
        assign: Assignments,
        state: ResumeState,
    ) -> Result<()> {
        self.tx
            .as_ref()
            .expect("checkpointer already finished")
            .send(SnapshotJob { iteration, fingerprint, assign, state })
            .map_err(|_| anyhow!("checkpoint writer thread exited early"))
    }

    /// Close the queue, drain every pending snapshot, and surface any
    /// write error. Dropping without calling this still drains, but
    /// swallows errors.
    pub fn finish(mut self) -> Result<()> {
        self.tx.take();
        match self.writer.take() {
            Some(h) => h.join().map_err(|_| anyhow!("checkpoint writer thread panicked"))?,
            None => Ok(()),
        }
    }
}

impl Drop for AsyncCheckpointer {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, GenSpec};
    use crate::util::rng::Pcg64;

    fn fixture() -> (Corpus, Assignments) {
        let corpus = generate(&GenSpec {
            vocab: 100,
            docs: 50,
            avg_doc_len: 15,
            zipf_s: 1.05,
            topics: 4,
            alpha: 0.1,
            seed: 77,
        });
        let mut rng = Pcg64::new(1);
        let assign = Assignments::random(&corpus, 12, &mut rng);
        (corpus, assign)
    }

    #[test]
    fn round_trip_preserves_state() {
        let (corpus, assign) = fixture();
        let mut buf = Vec::new();
        write_checkpoint(&mut buf, &assign, &corpus).unwrap();
        let loaded = read_checkpoint(&buf[..], &corpus).unwrap();
        assert_eq!(loaded.z, assign.z);
        assert_eq!(loaded.num_topics, 12);
        // Counts rebuilt from the restored Z match the originals.
        let (dt, wt, ck) = assign.build_counts(&corpus);
        loaded.check_consistency(&corpus, &dt, &wt, &ck).unwrap();
    }

    #[test]
    fn resumable_round_trip_preserves_trailer() {
        let (corpus, assign) = fixture();
        let (dt, _, _) = assign.build_counts(&corpus);
        let state = ResumeState {
            iteration: 17,
            worker_rng: vec![(1u128 << 70 | 3, 5), (u128::MAX - 9, 11)],
            dt: dt.clone(),
        };
        let mut buf = Vec::new();
        write_resumable(&mut buf, &assign, &corpus, &state).unwrap();
        let (loaded, trailer) = read_resumable(&buf[..], &corpus).unwrap();
        assert_eq!(loaded.z, assign.z);
        let trailer = trailer.expect("v2 checkpoint carries a trailer");
        assert_eq!(trailer.iteration, 17);
        assert_eq!(trailer.worker_rng, state.worker_rng);
        assert_eq!(trailer.dt.num_docs(), dt.num_docs());
        for d in 0..dt.num_docs() {
            // Entry *order* preserved verbatim, not just the map.
            let a: Vec<(u32, u32)> = trailer.dt.doc(d).iter().collect();
            let b: Vec<(u32, u32)> = dt.doc(d).iter().collect();
            assert_eq!(a, b, "doc {d}");
        }
    }

    #[test]
    fn plain_checkpoint_has_no_trailer() {
        let (corpus, assign) = fixture();
        let mut buf = Vec::new();
        write_checkpoint(&mut buf, &assign, &corpus).unwrap();
        let (_, trailer) = read_resumable(&buf[..], &corpus).unwrap();
        assert!(trailer.is_none());
    }

    #[test]
    fn corrupted_trailer_counts_rejected() {
        let (corpus, assign) = fixture();
        let (mut dt, _, _) = assign.build_counts(&corpus);
        // Shift one count so the trailer disagrees with Z.
        dt.doc_mut(0).inc(0);
        let state = ResumeState { iteration: 1, worker_rng: vec![(1, 1)], dt };
        let mut buf = Vec::new();
        write_resumable(&mut buf, &assign, &corpus, &state).unwrap();
        let err = read_resumable(&buf[..], &corpus).unwrap_err().to_string();
        assert!(err.contains("disagree"), "{err}");
    }

    #[test]
    fn wrong_corpus_rejected() {
        let (corpus, assign) = fixture();
        let mut buf = Vec::new();
        write_checkpoint(&mut buf, &assign, &corpus).unwrap();
        let other = generate(&GenSpec {
            vocab: 100,
            docs: 50,
            avg_doc_len: 15,
            zipf_s: 1.05,
            topics: 4,
            alpha: 0.1,
            seed: 78, // different corpus
        });
        let err = read_checkpoint(&buf[..], &other).unwrap_err().to_string();
        assert!(err.contains("different corpus"), "{err}");
    }

    #[test]
    fn garbage_rejected() {
        let (corpus, _) = fixture();
        assert!(read_checkpoint(&b"nonsense"[..], &corpus).is_err());
        let mut bad = MAGIC.to_vec();
        bad.push(99); // version 99
        assert!(read_checkpoint(&bad[..], &corpus).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let (corpus, assign) = fixture();
        for resumable in [false, true] {
            let mut buf = Vec::new();
            if resumable {
                let (dt, _, _) = assign.build_counts(&corpus);
                let state = ResumeState { iteration: 2, worker_rng: vec![(3, 7)], dt };
                write_resumable(&mut buf, &assign, &corpus, &state).unwrap();
            } else {
                write_checkpoint(&mut buf, &assign, &corpus).unwrap();
            }
            buf.truncate(buf.len() - 3);
            assert!(read_resumable(&buf[..], &corpus).is_err(), "resumable={resumable}");
        }
    }

    #[test]
    fn async_snapshots_land_atomically_and_latest_wins() {
        let (corpus, assign) = fixture();
        let (dt, _, _) = assign.build_counts(&corpus);
        let dir = std::env::temp_dir()
            .join(format!("mplda_async_ckpt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let fp = corpus_fingerprint(&corpus);
        let ck = AsyncCheckpointer::new(&dir).unwrap();
        assert_eq!(ck.dir(), dir.as_path());
        for iteration in [5usize, 10, 15] {
            let state =
                ResumeState { iteration, worker_rng: vec![(3, 7)], dt: dt.clone() };
            ck.submit(iteration, fp, assign.clone(), state).unwrap();
        }
        ck.finish().unwrap();
        // A stale in-flight temp file must never be chosen as latest.
        std::fs::write(dir.join("ckpt-99.mplda.tmp"), b"partial").unwrap();
        std::fs::write(dir.join("unrelated.txt"), b"x").unwrap();
        let (iter, path) = find_latest_checkpoint(&dir).unwrap().expect("snapshots exist");
        assert_eq!(iter, 15);
        // The published file is complete and loads with its trailer.
        let (loaded, trailer) = load_resumable(&path, &corpus).unwrap();
        assert_eq!(loaded.z, assign.z);
        assert_eq!(trailer.expect("v2 trailer").iteration, 15);
        // No temp droppings for completed snapshots.
        for it in [5usize, 10, 15] {
            assert!(dir.join(format!("ckpt-{it}.mplda")).exists());
            assert!(!dir.join(format!("ckpt-{it}.mplda.tmp")).exists());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn find_latest_handles_missing_and_empty_dirs() {
        let dir = std::env::temp_dir()
            .join(format!("mplda_no_such_dir_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        assert!(find_latest_checkpoint(&dir).unwrap().is_none());
        std::fs::create_dir_all(&dir).unwrap();
        assert!(find_latest_checkpoint(&dir).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_round_trip() {
        let (corpus, assign) = fixture();
        let path = std::env::temp_dir().join(format!("mplda_ckpt_{}.bin", std::process::id()));
        save(&path, &assign, &corpus).unwrap();
        let loaded = load(&path, &corpus).unwrap();
        assert_eq!(loaded.z, assign.z);
        std::fs::remove_file(&path).ok();
    }
}
