//! The LDA "model" state: count statistics and their partitioning.
//!
//! Collapsed Gibbs sampling maintains three statistics (§2.1):
//! `C_d^k` (doc–topic, [`doc_topic`]), `C_t^k` (word–topic, [`word_topic`])
//! and `C_k` (topic totals, [`topic_counts`]). The word–topic table is the
//! "big model" — `V × K` entries — and is what gets partitioned into
//! disjoint word [`block`]s and rotated between workers. [`wire`] defines
//! the byte format blocks travel in (its length is what the network
//! simulator charges), and [`init`] draws the initial topic assignments.

pub mod alias;
pub mod topic_counts;
pub mod doc_topic;
pub mod doc_view;
pub mod word_topic;
pub mod block;
pub mod init;
pub mod wire;
pub mod checkpoint;

pub use alias::{AliasCache, WordAlias};
pub use block::{BlockMap, ModelBlock};
pub use checkpoint::ResumeState;
pub use doc_topic::{DocTopic, SparseCounts};
pub use doc_view::{DocView, ShardOwnership};
pub use init::Assignments;
pub use topic_counts::TopicCounts;
pub use word_topic::{SparseRow, WordTopicTable};
