//! Document–topic counts `C_d^k`, stored sparse.
//!
//! Only `K_d ≪ K` topics have non-zero count in a document (§2.2); the
//! sparse samplers walk exactly those entries. [`SparseCounts`] keeps
//! entries **sorted by descending count** and maintains the order with
//! adjacent swaps on inc/dec — the bucket-walk then hits high-mass topics
//! first, shortening the expected scan (the SparseLDA trick, also used by
//! the paper's X+Y sampler for its `Y` bucket).

/// Sparse topic→count map, descending by count.
#[derive(Debug, Clone, Default)]
pub struct SparseCounts {
    entries: Vec<(u32, u32)>, // (topic, count), count > 0, desc by count
}

/// Equality is as a *map* (ties among equal counts may be ordered
/// differently depending on update history).
impl PartialEq for SparseCounts {
    fn eq(&self, other: &Self) -> bool {
        if self.entries.len() != other.entries.len() {
            return false;
        }
        let canon = |s: &SparseCounts| {
            let mut v = s.entries.clone();
            v.sort_unstable();
            v
        };
        canon(self) == canon(other)
    }
}

impl Eq for SparseCounts {}

impl SparseCounts {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild from entries **in their live storage order** (descending by
    /// count; ties in whatever order update history left them). Used by the
    /// resumable checkpoint: the bucket-walk order and floating-point
    /// summation order of the samplers depend on this order, so restoring
    /// it verbatim is what makes resume bitwise-deterministic. Entries must
    /// be positive-count, sorted descending, with no duplicate topics.
    pub fn from_ordered_entries(entries: Vec<(u32, u32)>) -> SparseCounts {
        debug_assert!(entries.iter().all(|&(_, c)| c > 0));
        debug_assert!(entries.windows(2).all(|w| w[0].1 >= w[1].1));
        SparseCounts { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Non-zero entries, descending by count.
    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.entries.iter().copied()
    }

    pub fn get(&self, topic: u32) -> u32 {
        self.entries
            .iter()
            .find(|&&(k, _)| k == topic)
            .map(|&(_, c)| c)
            .unwrap_or(0)
    }

    /// Increment `topic`; maintains descending order with adjacent bubbling.
    pub fn inc(&mut self, topic: u32) {
        match self.entries.iter().position(|&(k, _)| k == topic) {
            Some(i) => {
                self.entries[i].1 += 1;
                // Bubble towards the front while larger than predecessor.
                let mut i = i;
                while i > 0 && self.entries[i - 1].1 < self.entries[i].1 {
                    self.entries.swap(i - 1, i);
                    i -= 1;
                }
            }
            None => self.entries.push((topic, 1)),
        }
    }

    /// Decrement `topic` (must be present); removes at zero.
    pub fn dec(&mut self, topic: u32) {
        let i = self
            .entries
            .iter()
            .position(|&(k, _)| k == topic)
            .expect("dec of absent topic");
        self.entries[i].1 -= 1;
        if self.entries[i].1 == 0 {
            self.entries.remove(i);
        } else {
            let mut i = i;
            while i + 1 < self.entries.len() && self.entries[i + 1].1 > self.entries[i].1 {
                self.entries.swap(i, i + 1);
                i += 1;
            }
        }
    }

    /// Total count (= document length while consistent).
    pub fn total(&self) -> u64 {
        self.entries.iter().map(|&(_, c)| c as u64).sum()
    }

    /// Order invariant check (tests).
    pub fn is_sorted_desc(&self) -> bool {
        self.entries.windows(2).all(|w| w[0].1 >= w[1].1)
    }

    /// Approximate heap bytes.
    pub fn bytes(&self) -> u64 {
        (self.entries.capacity() * 8 + 24) as u64
    }
}

/// All documents' topic counts for one worker shard (indexed by global doc
/// id through a dense map owned by the caller) or the whole corpus.
#[derive(Debug, Clone, Default)]
pub struct DocTopic {
    pub docs: Vec<SparseCounts>,
}

impl DocTopic {
    pub fn zeros(num_docs: usize) -> Self {
        DocTopic { docs: vec![SparseCounts::new(); num_docs] }
    }

    pub fn num_docs(&self) -> usize {
        self.docs.len()
    }

    #[inline]
    pub fn doc(&self, d: usize) -> &SparseCounts {
        &self.docs[d]
    }

    #[inline]
    pub fn doc_mut(&mut self, d: usize) -> &mut SparseCounts {
        &mut self.docs[d]
    }

    /// Mean `K_d` (avg non-zero topics per doc) — the sparsity statistic
    /// that drives sparse-sampler complexity.
    pub fn avg_kd(&self) -> f64 {
        if self.docs.is_empty() {
            return 0.0;
        }
        self.docs.iter().map(|d| d.len()).sum::<usize>() as f64 / self.docs.len() as f64
    }

    pub fn bytes(&self) -> u64 {
        self.docs.iter().map(|d| d.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn inc_dec_roundtrip() {
        let mut c = SparseCounts::new();
        c.inc(5);
        c.inc(5);
        c.inc(2);
        assert_eq!(c.get(5), 2);
        assert_eq!(c.get(2), 1);
        assert_eq!(c.get(9), 0);
        c.dec(5);
        c.dec(5);
        assert_eq!(c.get(5), 0);
        assert_eq!(c.len(), 1);
        assert_eq!(c.total(), 1);
    }

    #[test]
    #[should_panic(expected = "absent topic")]
    fn dec_absent_panics() {
        let mut c = SparseCounts::new();
        c.dec(3);
    }

    #[test]
    fn stays_sorted_under_random_ops() {
        let mut rng = Pcg64::new(8);
        let mut c = SparseCounts::new();
        let mut reference = std::collections::HashMap::new();
        for _ in 0..5_000 {
            let k = rng.next_below(20) as u32;
            let cur = *reference.get(&k).unwrap_or(&0u32);
            if cur > 0 && rng.next_f64() < 0.45 {
                c.dec(k);
                if cur == 1 {
                    reference.remove(&k);
                } else {
                    reference.insert(k, cur - 1);
                }
            } else {
                c.inc(k);
                reference.insert(k, cur + 1);
            }
            assert!(c.is_sorted_desc());
        }
        for (&k, &v) in &reference {
            assert_eq!(c.get(k), v);
        }
        assert_eq!(c.len(), reference.len());
    }

    #[test]
    fn avg_kd() {
        let mut dt = DocTopic::zeros(2);
        dt.doc_mut(0).inc(1);
        dt.doc_mut(0).inc(2);
        dt.doc_mut(1).inc(1);
        assert!((dt.avg_kd() - 1.5).abs() < 1e-12);
    }
}
