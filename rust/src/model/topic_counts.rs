//! Global topic totals `C_k` — the non-separable dependency (§3.3).
//!
//! `C_k = Σ_t C_t^k` is needed in every sampling step's denominator and
//! cannot be partitioned by words. The paper's protocol: workers read a
//! snapshot at round start, accumulate local deltas while sampling, and
//! merge deltas back at round end. [`TopicCounts`] is the value type used
//! for both the authoritative copy (in the KV-store) and worker snapshots;
//! [`TopicCounts::l1_distance`] implements the `Δ_{r,i}` numerator of Fig 3.

/// Topic-total vector `C_k` (signed internally so transient deltas can dip
/// below zero before a merge completes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopicCounts {
    counts: Vec<i64>,
}

impl TopicCounts {
    pub fn zeros(k: usize) -> Self {
        TopicCounts { counts: vec![0; k] }
    }

    pub fn from_vec(counts: Vec<i64>) -> Self {
        TopicCounts { counts }
    }

    pub fn num_topics(&self) -> usize {
        self.counts.len()
    }

    #[inline]
    pub fn get(&self, k: usize) -> i64 {
        self.counts[k]
    }

    #[inline]
    pub fn set(&mut self, k: usize, v: i64) {
        self.counts[k] = v;
    }

    #[inline]
    pub fn inc(&mut self, k: usize) {
        self.counts[k] += 1;
    }

    #[inline]
    pub fn dec(&mut self, k: usize) {
        self.counts[k] -= 1;
    }

    pub fn as_slice(&self) -> &[i64] {
        &self.counts
    }

    /// Total token mass `N = Σ_k C_k`.
    pub fn total(&self) -> i64 {
        self.counts.iter().sum()
    }

    /// `self += other` (merging a worker's delta).
    pub fn merge(&mut self, delta: &TopicCounts) {
        assert_eq!(self.counts.len(), delta.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&delta.counts) {
            *a += b;
        }
    }

    /// `self - other` as a new delta.
    pub fn diff(&self, other: &TopicCounts) -> TopicCounts {
        assert_eq!(self.counts.len(), other.counts.len());
        TopicCounts {
            counts: self.counts.iter().zip(&other.counts).map(|(a, b)| a - b).collect(),
        }
    }

    /// `‖self − other‖₁` — numerator of the paper's `Δ_{r,i}` error metric.
    pub fn l1_distance(&self, other: &TopicCounts) -> u64 {
        assert_eq!(self.counts.len(), other.counts.len());
        self.counts
            .iter()
            .zip(&other.counts)
            .map(|(a, b)| a.abs_diff(*b))
            .sum()
    }

    /// All entries non-negative (health check after merges).
    pub fn is_valid(&self) -> bool {
        self.counts.iter().all(|&c| c >= 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inc_dec_total() {
        let mut c = TopicCounts::zeros(4);
        c.inc(0);
        c.inc(0);
        c.inc(3);
        c.dec(0);
        assert_eq!(c.get(0), 1);
        assert_eq!(c.get(3), 1);
        assert_eq!(c.total(), 2);
        assert!(c.is_valid());
    }

    #[test]
    fn merge_and_diff_are_inverses() {
        let a = TopicCounts::from_vec(vec![5, 3, 0, 2]);
        let b = TopicCounts::from_vec(vec![4, 3, 1, 0]);
        let delta = a.diff(&b);
        let mut rebuilt = b.clone();
        rebuilt.merge(&delta);
        assert_eq!(rebuilt, a);
    }

    #[test]
    fn l1_distance_matches_fig3_definition() {
        let t = TopicCounts::from_vec(vec![10, 20, 30]);
        let tm = TopicCounts::from_vec(vec![12, 18, 30]);
        assert_eq!(t.l1_distance(&tm), 4);
        assert_eq!(t.l1_distance(&t), 0);
    }

    #[test]
    fn validity_detects_negative() {
        let c = TopicCounts::from_vec(vec![1, -1]);
        assert!(!c.is_valid());
    }
}
