//! Per-word alias tables for the Metropolis–Hastings sampler
//! (`sampler::mh_alias`), cached on the [`crate::model::ModelBlock`]
//! they serve.
//!
//! LightLDA's observation (Yuan et al., 2015 — see PAPERS.md) is that the
//! word-side factor of eq. 1 can be turned into an **O(1) proposal**: build
//! a Walker alias table over `q_w(k) ∝ C_t^k + β` once per word, draw from
//! it in constant time, and let a Metropolis–Hastings acceptance step
//! correct for both the missing doc/totals factors *and* the table going
//! stale as sampling mutates the row. Staleness is therefore a **quality
//! knob, not a correctness risk**: the acceptance ratio divides by the
//! exact pmf that was drawn from (the stale one, recorded in
//! [`WordAlias::weight`]), so the chain's stationary distribution is the
//! exact eq. 1 conditional no matter how old the table is.
//!
//! ## Cache lifecycle
//!
//! ```text
//! lease ──► prepare_block builds tables lazily (shard ∩ block words only)
//!   │            │  bytes capped by `train.alias_budget_mib` per block
//!   │            ▼
//!   │       sample_block draws O(1) word proposals from the cache
//!   ▼
//! commit ──► KvStore clears the slot — staged/re-leased blocks start fresh
//! ```
//!
//! The slot is deliberately **transparent to block identity**: it never
//! serializes ([`crate::model::wire`] ignores it), never participates in
//! equality or digests, and a clone starts empty. That is what keeps the
//! pipelined prefetch engine's staged blocks bitwise-interchangeable with
//! synchronously fetched ones.

use crate::util::rng::{AliasTable, Pcg64};

use super::word_topic::SparseRow;

/// One word's proposal table: `q_w(k) ∝ ct_stale[k] + β`, drawn in O(1)
/// by splitting the mass into the row's count part (alias table over the
/// non-zero support) and the `βK` smoothing part (uniform topic).
#[derive(Debug, Clone)]
pub struct WordAlias {
    /// `(topic, count)` support of the row **at build time** (ascending by
    /// topic — the stale snapshot the proposal pmf is defined over).
    entries: Vec<(u32, u32)>,
    /// Walker table over `entries` weighted by count (`None` ⇔ empty row).
    table: Option<AliasTable>,
    /// Σ stale counts.
    row_total: u64,
}

impl WordAlias {
    /// Snapshot `row` and build its Walker table. `weights` is a reusable
    /// scratch buffer (no steady-state allocation beyond the table itself).
    pub fn build(row: &SparseRow, weights: &mut Vec<f64>) -> WordAlias {
        let entries: Vec<(u32, u32)> = row.iter().collect();
        let row_total: u64 = entries.iter().map(|&(_, c)| c as u64).sum();
        let table = if entries.is_empty() {
            None
        } else {
            weights.clear();
            weights.extend(entries.iter().map(|&(_, c)| c as f64));
            Some(AliasTable::new(weights))
        };
        WordAlias { entries, table, row_total }
    }

    /// Draw a topic from `q_w(k) ∝ ct_stale[k] + β` over `num_topics`
    /// topics. O(1): one branch draw, then either an alias draw over the
    /// non-zero support or a uniform topic.
    #[inline]
    pub fn draw(&self, num_topics: usize, beta: f64, rng: &mut Pcg64) -> u32 {
        let count_mass = self.row_total as f64;
        let total = count_mass + beta * num_topics as f64;
        let u = rng.next_f64() * total;
        if u < count_mass {
            // row_total > 0 here, so the table exists.
            let table = self.table.as_ref().expect("non-empty row has a table");
            self.entries[table.sample(rng)].0
        } else {
            rng.index(num_topics) as u32
        }
    }

    /// Unnormalized proposal weight `q_w(k) ∝ ct_stale[k] + β` — the exact
    /// pmf [`WordAlias::draw`] samples from, which the MH acceptance ratio
    /// divides by (this is the stale-count tolerance: the correction uses
    /// the snapshot, not the live row).
    #[inline]
    pub fn weight(&self, topic: u32, beta: f64) -> f64 {
        let c = match self.entries.binary_search_by_key(&topic, |&(k, _)| k) {
            Ok(i) => self.entries[i].1,
            Err(_) => 0,
        };
        c as f64 + beta
    }

    /// Approximate heap bytes: support entries (8 B) plus the Walker
    /// table's probability/alias arrays (8 + 4 B per entry).
    pub fn bytes(&self) -> u64 {
        let per_entry = if self.table.is_some() { 8 + 8 + 4 } else { 8 };
        (self.entries.len() * per_entry + 48) as u64
    }
}

/// All of one block's cached word tables, under a byte budget. Indexed by
/// the block's row index (`(word - lo) / stride`).
#[derive(Debug, Clone)]
pub struct AliasCache {
    tables: Vec<Option<Box<WordAlias>>>,
    bytes: u64,
    budget: u64,
    skipped: u64,
}

impl AliasCache {
    /// An empty cache for a block with `rows` word rows and a byte budget
    /// (`0` = unlimited).
    pub fn new(rows: usize, budget: u64) -> AliasCache {
        AliasCache { tables: vec![None; rows], bytes: 0, budget, skipped: 0 }
    }

    /// The cached table for row `idx`, if one was built and fit the budget.
    #[inline]
    pub fn get(&self, idx: usize) -> Option<&WordAlias> {
        self.tables[idx].as_deref()
    }

    /// Build (or keep) row `idx`'s table. Returns `false` when the byte
    /// budget rejected it — the kernel then falls back to a uniform word
    /// proposal for that word, degrading mixing, never correctness.
    pub fn build(&mut self, idx: usize, row: &SparseRow, weights: &mut Vec<f64>) -> bool {
        if self.tables[idx].is_some() {
            return true;
        }
        let table = WordAlias::build(row, weights);
        let add = table.bytes();
        if self.budget != 0 && self.bytes + add > self.budget {
            self.skipped += 1;
            return false;
        }
        self.bytes += add;
        self.tables[idx] = Some(Box::new(table));
        true
    }

    /// Heap bytes of every cached table (what the driver charges to
    /// [`crate::cluster::MemCategory::AliasCache`]).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Tables rejected by the budget since construction.
    pub fn skipped(&self) -> u64 {
        self.skipped
    }
}

/// The alias-cache slot a [`crate::model::ModelBlock`] carries. Transparent
/// to block identity: clones start empty, equality always holds, and the
/// KV-store clears the slot on commit so every lease starts fresh.
#[derive(Debug, Default)]
pub struct AliasSlot(Option<Box<AliasCache>>);

impl AliasSlot {
    /// The cache, creating an empty one sized for `rows` rows on first use.
    /// An existing cache keeps its budget (it was created this lease).
    pub fn ensure(&mut self, rows: usize, budget: u64) -> &mut AliasCache {
        self.0.get_or_insert_with(|| Box::new(AliasCache::new(rows, budget)))
    }

    /// The cache, if any tables were built this lease.
    #[inline]
    pub fn get(&self) -> Option<&AliasCache> {
        self.0.as_deref()
    }

    /// Drop every cached table (commit-time invalidation).
    pub fn clear(&mut self) {
        self.0 = None;
    }

    /// Cached bytes (0 when empty).
    pub fn bytes(&self) -> u64 {
        self.0.as_ref().map_or(0, |c| c.bytes())
    }
}

/// Caches are lease-scoped: a cloned block (tests, benches, wire decode)
/// starts with an empty slot, exactly like a freshly leased one.
impl Clone for AliasSlot {
    fn clone(&self) -> AliasSlot {
        AliasSlot(None)
    }
}

/// The slot never participates in block identity — two blocks with equal
/// rows are equal whatever either one has cached.
impl PartialEq for AliasSlot {
    fn eq(&self, _: &AliasSlot) -> bool {
        true
    }
}

impl Eq for AliasSlot {}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(entries: &[(u32, u32)]) -> SparseRow {
        SparseRow::from_entries(entries.to_vec())
    }

    #[test]
    fn draw_matches_proposal_distribution() {
        // Empirical draw frequencies must match q(k) ∝ ct[k] + β.
        let r = row(&[(1, 6), (4, 2)]);
        let mut weights = Vec::new();
        let a = WordAlias::build(&r, &mut weights);
        let k = 8;
        let beta = 0.25;
        let mut rng = Pcg64::new(11);
        let n = 200_000;
        let mut counts = vec![0u64; k];
        for _ in 0..n {
            counts[a.draw(k, beta, &mut rng) as usize] += 1;
        }
        let total: f64 = (0..k as u32).map(|t| a.weight(t, beta)).sum();
        for t in 0..k {
            let expect = a.weight(t as u32, beta) / total;
            let got = counts[t] as f64 / n as f64;
            assert!(
                (got - expect).abs() < 0.01,
                "topic {t}: got {got:.4} expect {expect:.4}"
            );
        }
    }

    #[test]
    fn empty_row_draws_uniform() {
        let a = WordAlias::build(&row(&[]), &mut Vec::new());
        let mut rng = Pcg64::new(3);
        let mut seen = [false; 4];
        for _ in 0..1_000 {
            seen[a.draw(4, 0.1, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(a.weight(2, 0.1), 0.1);
    }

    #[test]
    fn weight_reads_stale_snapshot() {
        // The table keeps the build-time counts even after the row moves on.
        let mut r = row(&[(2, 5)]);
        let a = WordAlias::build(&r, &mut Vec::new());
        r.inc(2);
        r.inc(3);
        assert_eq!(a.weight(2, 0.0), 5.0, "weight must be the stale count");
        assert_eq!(a.weight(3, 0.0), 0.0);
    }

    #[test]
    fn cache_budget_rejects_and_counts() {
        let r = row(&[(0, 1), (1, 2), (2, 3)]);
        let mut weights = Vec::new();
        let mut unlimited = AliasCache::new(4, 0);
        assert!(unlimited.build(0, &r, &mut weights));
        assert!(unlimited.bytes() > 0);
        // A 1-byte budget rejects everything.
        let mut capped = AliasCache::new(4, 1);
        assert!(!capped.build(0, &r, &mut weights));
        assert_eq!(capped.bytes(), 0);
        assert_eq!(capped.skipped(), 1);
        assert!(capped.get(0).is_none());
        // Rebuild of a cached row is a no-op hit.
        assert!(unlimited.build(0, &r, &mut weights));
        assert_eq!(unlimited.skipped(), 0);
    }

    #[test]
    fn slot_is_identity_transparent() {
        let mut a = AliasSlot::default();
        let b = AliasSlot::default();
        a.ensure(2, 0).build(0, &row(&[(1, 3)]), &mut Vec::new());
        assert!(a.bytes() > 0);
        assert_eq!(a, b, "cache contents must not affect equality");
        let c = a.clone();
        assert_eq!(c.bytes(), 0, "clones start with an empty cache");
        a.clear();
        assert_eq!(a.bytes(), 0);
        assert!(a.get().is_none());
    }
}
