//! Disjoint per-document views over the global training state.
//!
//! The driver keeps two document-indexed structures that *every* worker
//! writes into during a round: the topic assignments `z` (one `Vec<u32>`
//! per document) and the doc–topic counts `C_d^k` ([`DocTopic`]). The
//! paper's correctness argument (§3.1) is that these writes never
//! conflict: each document belongs to exactly one worker's shard, so the
//! workers' row sets are disjoint. This module turns that argument into
//! types:
//!
//! * [`ShardOwnership`] — built **once** per training run from the data
//!   partition; validates that shards are pairwise disjoint and in-bounds
//!   and records each document's owner in a dense map.
//! * [`DocView`] — hands out `&mut` access to individual document rows.
//!   Views produced by [`DocView::split_disjoint`] verify **every access**
//!   against the ownership map (an O(1) array compare, enforced in release
//!   builds too), so the `unsafe` aliasing below can never be reached with
//!   overlapping rows from safe code — a contract violation panics instead.
//!
//! Sequential callers use [`DocView::new`], which wraps ordinary exclusive
//! borrows, involves no aliasing at all, and skips the ownership check.

use std::marker::PhantomData;

use super::doc_topic::{DocTopic, SparseCounts};

/// Sentinel in the owner map for "no shard owns this document".
const UNOWNED: u32 = u32::MAX;

/// Validated doc → owning-shard map, reusable across rounds (the partition
/// is fixed for a whole training run, so validation cost is paid once, not
/// per round).
pub struct ShardOwnership {
    owner_of: Box<[u32]>,
    num_shards: u32,
}

impl ShardOwnership {
    /// Build from one doc-id list per shard. Panics (protocol violation,
    /// not a recoverable error) unless every doc id is in-bounds and
    /// appears in at most one shard — the §3.1 disjointness invariant.
    pub fn build(shards: &[&[u32]], num_docs: usize) -> ShardOwnership {
        assert!((shards.len() as u64) < UNOWNED as u64, "too many shards");
        let mut owner_of = vec![UNOWNED; num_docs].into_boxed_slice();
        for (w, shard) in shards.iter().enumerate() {
            for &d in *shard {
                let d = d as usize;
                assert!(d < num_docs, "doc id {d} out of range ({num_docs} docs)");
                assert!(
                    owner_of[d] == UNOWNED,
                    "doc {d} appears in two shards — views would alias"
                );
                owner_of[d] = w as u32;
            }
        }
        ShardOwnership { owner_of, num_shards: shards.len() as u32 }
    }

    pub fn num_shards(&self) -> usize {
        self.num_shards as usize
    }

    pub fn num_docs(&self) -> usize {
        self.owner_of.len()
    }

    /// Owning shard of document `d`, if any.
    pub fn owner(&self, d: usize) -> Option<usize> {
        match self.owner_of[d] {
            UNOWNED => None,
            w => Some(w as usize),
        }
    }
}

/// Mutable view of document rows (assignments + doc–topic counts),
/// restricted to one shard when produced by [`DocView::split_disjoint`].
pub struct DocView<'a> {
    z: *mut Vec<u32>,
    dt: *mut SparseCounts,
    len: usize,
    /// `(my shard index, doc → owner map)`; `None` = unrestricted
    /// exclusive view from [`DocView::new`].
    owner: Option<(u32, &'a [u32])>,
    _borrow: PhantomData<&'a mut Vec<u32>>,
}

// SAFETY: a view only dereferences rows it is allowed to touch. Views made
// by `new` hold genuinely exclusive borrows. Views made by `split_disjoint`
// check every access against a `ShardOwnership` whose construction proved
// the shards pairwise disjoint, so two views sent to two threads can never
// produce overlapping references — a violating access panics before the
// raw pointer is dereferenced, in release builds too.
unsafe impl Send for DocView<'_> {}

impl<'a> DocView<'a> {
    /// Wrap exclusive borrows of the full state (sequential execution; no
    /// aliasing — the borrows stay exclusive for the view's lifetime).
    pub fn new(z: &'a mut [Vec<u32>], dt: &'a mut DocTopic) -> DocView<'a> {
        assert_eq!(z.len(), dt.num_docs(), "z and doc-topic row counts differ");
        let len = z.len();
        DocView {
            z: z.as_mut_ptr(),
            dt: dt.docs.as_mut_ptr(),
            len,
            owner: None,
            _borrow: PhantomData,
        }
    }

    /// Split the state into one view per shard of `ownership` (built once
    /// via [`ShardOwnership::build`], which is where disjointness was
    /// validated).
    pub fn split_disjoint(
        z: &'a mut [Vec<u32>],
        dt: &'a mut DocTopic,
        ownership: &'a ShardOwnership,
    ) -> Vec<DocView<'a>> {
        assert_eq!(z.len(), dt.num_docs(), "z and doc-topic row counts differ");
        assert_eq!(
            z.len(),
            ownership.num_docs(),
            "ownership map was built for a different corpus"
        );
        let len = z.len();
        let zp = z.as_mut_ptr();
        let dp = dt.docs.as_mut_ptr();
        (0..ownership.num_shards)
            .map(|w| DocView {
                z: zp,
                dt: dp,
                len,
                owner: Some((w, &ownership.owner_of[..])),
                _borrow: PhantomData,
            })
            .collect()
    }

    /// Documents in the underlying state (not the shard size).
    pub fn num_docs(&self) -> usize {
        self.len
    }

    #[inline]
    fn check(&self, d: usize) {
        assert!(d < self.len, "doc id {d} out of range ({} docs)", self.len);
        if let Some((me, owner_of)) = self.owner {
            assert!(
                owner_of[d] == me,
                "doc {d} accessed by shard-view {me} which does not own it"
            );
        }
    }

    /// Topic assignments of document `d`.
    #[inline]
    pub fn z_row(&self, d: usize) -> &[u32] {
        self.check(d);
        // SAFETY: in-bounds and owned by this view (checked above).
        unsafe { &*self.z.add(d) }
    }

    /// Mutable topic assignments of document `d`.
    #[inline]
    pub fn z_row_mut(&mut self, d: usize) -> &mut [u32] {
        self.check(d);
        // SAFETY: as above; `&mut self` prevents overlap within the view.
        unsafe { &mut *self.z.add(d) }
    }

    /// Doc–topic counts of document `d`.
    #[inline]
    pub fn doc(&self, d: usize) -> &SparseCounts {
        self.check(d);
        // SAFETY: as above.
        unsafe { &*self.dt.add(d) }
    }

    /// Mutable doc–topic counts of document `d`.
    #[inline]
    pub fn doc_mut(&mut self, d: usize) -> &mut SparseCounts {
        self.check(d);
        // SAFETY: as above.
        unsafe { &mut *self.dt.add(d) }
    }

    /// Document `d`'s topic counts and its mutable assignment row,
    /// together — for kernels that update `z` mid-token while reading
    /// `C_d` (the MH kernel's live-state doc proposal).
    #[inline]
    pub fn doc_and_z_mut(&mut self, d: usize) -> (&SparseCounts, &mut [u32]) {
        self.check(d);
        // SAFETY: as above; the counts and the assignment row are
        // distinct allocations, and `&mut self` keeps the pair exclusive
        // within this view.
        unsafe { (&*self.dt.add(d), &mut *self.z.add(d)) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(docs: usize) -> (Vec<Vec<u32>>, DocTopic) {
        let z: Vec<Vec<u32>> = (0..docs).map(|d| vec![d as u32; 3]).collect();
        let dt = DocTopic::zeros(docs);
        (z, dt)
    }

    #[test]
    fn full_view_reads_and_writes() {
        let (mut z, mut dt) = state(4);
        let mut v = DocView::new(&mut z, &mut dt);
        assert_eq!(v.num_docs(), 4);
        assert_eq!(v.z_row(2)[0], 2);
        v.z_row_mut(2)[0] = 9;
        v.doc_mut(3).inc(5);
        assert_eq!(v.doc(3).get(5), 1);
        drop(v);
        assert_eq!(z[2][0], 9);
        assert_eq!(dt.doc(3).get(5), 1);
    }

    #[test]
    fn ownership_map_records_owners() {
        let a: Vec<u32> = vec![0, 2];
        let b: Vec<u32> = vec![1];
        let own = ShardOwnership::build(&[a.as_slice(), b.as_slice()], 4);
        assert_eq!(own.num_shards(), 2);
        assert_eq!(own.num_docs(), 4);
        assert_eq!(own.owner(0), Some(0));
        assert_eq!(own.owner(1), Some(1));
        assert_eq!(own.owner(2), Some(0));
        assert_eq!(own.owner(3), None);
    }

    #[test]
    fn split_gives_independent_views() {
        let (mut z, mut dt) = state(6);
        let a: Vec<u32> = vec![0, 2, 4];
        let b: Vec<u32> = vec![1, 3, 5];
        let own = ShardOwnership::build(&[a.as_slice(), b.as_slice()], 6);
        let mut views = DocView::split_disjoint(&mut z, &mut dt, &own);
        let mut vb = views.pop().unwrap();
        let mut va = views.pop().unwrap();
        va.z_row_mut(0)[1] = 100;
        vb.z_row_mut(1)[1] = 200;
        va.doc_mut(4).inc(1);
        vb.doc_mut(5).inc(2);
        drop((va, vb));
        assert_eq!(z[0][1], 100);
        assert_eq!(z[1][1], 200);
        assert_eq!(dt.doc(4).get(1), 1);
        assert_eq!(dt.doc(5).get(2), 1);
    }

    #[test]
    fn split_views_work_across_threads() {
        let docs = 64;
        let (mut z, mut dt) = state(docs);
        let evens: Vec<u32> = (0..docs as u32).filter(|d| d % 2 == 0).collect();
        let odds: Vec<u32> = (0..docs as u32).filter(|d| d % 2 == 1).collect();
        let own = ShardOwnership::build(&[evens.as_slice(), odds.as_slice()], docs);
        let views = DocView::split_disjoint(&mut z, &mut dt, &own);
        let shards = [evens.clone(), odds.clone()];
        std::thread::scope(|s| {
            for (mut view, shard) in views.into_iter().zip(shards.iter()) {
                s.spawn(move || {
                    for &d in shard {
                        view.z_row_mut(d as usize)[0] = d + 1000;
                        view.doc_mut(d as usize).inc(d % 7);
                    }
                });
            }
        });
        for d in 0..docs {
            assert_eq!(z[d][0], d as u32 + 1000);
            assert_eq!(dt.doc(d).get(d as u32 % 7), 1);
        }
    }

    #[test]
    #[should_panic(expected = "two shards")]
    fn overlapping_shards_rejected() {
        let a: Vec<u32> = vec![0, 1];
        let b: Vec<u32> = vec![1, 2];
        let _ = ShardOwnership::build(&[a.as_slice(), b.as_slice()], 4);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_bounds_shard_rejected() {
        let a: Vec<u32> = vec![0, 9];
        let _ = ShardOwnership::build(&[a.as_slice()], 4);
    }

    #[test]
    #[should_panic(expected = "does not own")]
    fn unowned_access_panics_even_in_release() {
        // The ownership check is unconditional — a shard view touching a
        // document outside its shard must die loudly, not race.
        let (mut z, mut dt) = state(4);
        let a: Vec<u32> = vec![0, 1];
        let b: Vec<u32> = vec![2, 3];
        let own = ShardOwnership::build(&[a.as_slice(), b.as_slice()], 4);
        let mut views = DocView::split_disjoint(&mut z, &mut dt, &own);
        let mut va = views.remove(0);
        let _ = va.z_row_mut(2); // doc 2 belongs to shard 1
    }
}
