//! Block payload codecs for the on-disk tier.
//!
//! A spilled [`ModelBlock`] is serialized to a byte payload before being
//! appended to its home's [`segment`](super::segment) file. Two encodings
//! exist:
//!
//! * [`Encoding::Wire`] — the existing `model::wire` varint-delta codec,
//!   verbatim (`storage.compression = "none"`). Already compact for dense
//!   blocks; one byte per empty row.
//! * [`Encoding::Sparse`] — a compressed sparse row layout for long-tail
//!   word–topic data (`storage.compression = "sparse"`): the per-row
//!   lengths are run-length encoded, so a cold block whose rows are
//!   overwhelmingly empty costs disk bytes proportional to its non-zeros
//!   (plus one `(runlen, nnz)` varint pair per *run* of equal-length
//!   rows), not `V_block × K`.
//!
//! Both encodings are **lossless**: decode(encode(b)) reconstructs `b`
//! exactly (rows, range, stride; the alias slot is rebuilt empty, which
//! matches a block's post-commit state). This is the foundation of the
//! out-of-core bitwise-equality bar — see DESIGN.md §Storage.

use anyhow::{bail, ensure, Context, Result};

use crate::model::block::ModelBlock;
use crate::model::wire::{get_varint, put_varint};
use crate::model::word_topic::SparseRow;

/// How a segment payload is encoded. The tag byte is stored in every
/// segment record so a segment can mix encodings (e.g. after a config
/// change followed by crash recovery).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    /// `model::wire::encode_block` — varint topic-deltas, dense row list.
    Wire,
    /// Compressed sparse rows: RLE row-length table + varint entries.
    Sparse,
}

impl Encoding {
    /// Single-byte on-disk tag.
    pub fn tag(self) -> u8 {
        match self {
            Encoding::Wire => 0,
            Encoding::Sparse => 1,
        }
    }

    /// Inverse of [`Encoding::tag`].
    pub fn from_tag(tag: u8) -> Result<Encoding> {
        match tag {
            0 => Ok(Encoding::Wire),
            1 => Ok(Encoding::Sparse),
            other => bail!("unknown storage encoding tag {other}"),
        }
    }
}

/// Encode a block under the given encoding.
pub fn encode_block(block: &ModelBlock, encoding: Encoding) -> Vec<u8> {
    match encoding {
        Encoding::Wire => crate::model::wire::encode_block(block),
        Encoding::Sparse => encode_sparse(block),
    }
}

/// Decode a payload produced by [`encode_block`] under the same encoding.
pub fn decode_block(buf: &[u8], encoding: Encoding) -> Result<ModelBlock> {
    match encoding {
        Encoding::Wire => crate::model::wire::decode_block(buf),
        Encoding::Sparse => decode_sparse(buf),
    }
}

/// Compressed-sparse-row block layout:
///
/// ```text
/// header  := id:u32le  lo:u32le  hi:u32le  stride:varint  nrows:varint
/// rowlens := (runlen:varint  nnz:varint)*     Σ runlen == nrows
/// entries := per row, nnz × (topic_delta:varint  count:varint)
/// ```
///
/// Topic ids within a row are strictly increasing, so they are stored as
/// deltas from the previous topic (first entry: the topic itself), exactly
/// as in `model::wire`. The row-length table collapses runs of equal-nnz
/// rows — on long-tail data the dominant run is `nnz == 0`.
fn encode_sparse(block: &ModelBlock) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + block.nnz() * 2);
    out.extend_from_slice(&block.id.to_le_bytes());
    out.extend_from_slice(&block.lo.to_le_bytes());
    out.extend_from_slice(&block.hi.to_le_bytes());
    put_varint(&mut out, block.stride as u64);
    put_varint(&mut out, block.rows.len() as u64);
    // RLE row-length table.
    let mut i = 0;
    while i < block.rows.len() {
        let nnz = block.rows[i].nnz();
        let mut run = 1u64;
        while i + (run as usize) < block.rows.len() && block.rows[i + run as usize].nnz() == nnz {
            run += 1;
        }
        put_varint(&mut out, run);
        put_varint(&mut out, nnz as u64);
        i += run as usize;
    }
    // Entry table.
    for row in &block.rows {
        let mut prev = 0u32;
        for (k, c) in row.iter() {
            put_varint(&mut out, (k - prev) as u64);
            put_varint(&mut out, c as u64);
            prev = k;
        }
    }
    out
}

fn decode_sparse(buf: &[u8]) -> Result<ModelBlock> {
    ensure!(buf.len() >= 12, "sparse block header truncated");
    let id = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    let lo = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let hi = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    let mut pos = 12;
    let stride = get_varint(buf, &mut pos).context("sparse block stride")? as u32;
    ensure!(stride > 0, "zero stride in sparse block");
    let nrows = get_varint(buf, &mut pos).context("sparse block row count")? as usize;
    ensure!(hi >= lo, "inverted word range [{lo},{hi})");
    let expect = ((hi - lo) as usize).div_ceil(stride as usize);
    ensure!(
        nrows == expect,
        "row count {nrows} does not match range [{lo},{hi}) stride {stride}"
    );
    // RLE row-length table.
    let mut row_nnz = Vec::with_capacity(nrows);
    while row_nnz.len() < nrows {
        let run = get_varint(buf, &mut pos).context("sparse block run length")? as usize;
        let nnz = get_varint(buf, &mut pos).context("sparse block run nnz")? as usize;
        ensure!(run > 0, "zero-length run in sparse block row table");
        ensure!(
            row_nnz.len() + run <= nrows,
            "row-length runs overflow row count {nrows}"
        );
        for _ in 0..run {
            row_nnz.push(nnz);
        }
    }
    // Every entry costs at least two bytes (two varints), so the claimed
    // totals are bounded by the remaining buffer — reject hostile counts
    // before any `with_capacity` trusts them.
    let total_nnz = row_nnz.iter().fold(0u64, |a, &n| a.saturating_add(n as u64));
    ensure!(
        total_nnz <= (buf.len() - pos) as u64 / 2,
        "entry table claims {total_nnz} entries but only {} bytes remain",
        buf.len() - pos
    );
    // Entry table.
    let mut rows = Vec::with_capacity(nrows);
    for (r, &nnz) in row_nnz.iter().enumerate() {
        let mut entries = Vec::with_capacity(nnz);
        let mut prev = 0u64;
        for _ in 0..nnz {
            let dk = get_varint(buf, &mut pos).with_context(|| format!("row {r} topic delta"))?;
            let c = get_varint(buf, &mut pos).with_context(|| format!("row {r} count"))?;
            let k = prev + dk;
            ensure!(k <= u32::MAX as u64, "topic id {k} out of range in row {r}");
            ensure!(c > 0 && c <= u32::MAX as u64, "bad count {c} in row {r}");
            entries.push((k as u32, c as u32));
            prev = k;
        }
        rows.push(SparseRow::from_entries(entries));
    }
    ensure!(pos == buf.len(), "trailing bytes after sparse block");
    Ok(ModelBlock { id, lo, hi, stride, rows, alias: Default::default() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn sample_block(seed: u64, lo: u32, hi: u32, k: u32, fill: f64) -> ModelBlock {
        let mut b = ModelBlock::empty(7, lo, hi);
        let mut rng = Pcg64::new(seed);
        for w in lo..hi {
            for t in 0..k {
                if rng.next_f64() < fill {
                    let c = 1 + rng.next_below(40) as u32;
                    for _ in 0..c {
                        b.row_mut(w).inc(t);
                    }
                }
            }
        }
        b
    }

    #[test]
    fn sparse_round_trip_dense_and_longtail() {
        for fill in [0.0, 0.02, 0.5, 1.0] {
            let b = sample_block(9, 30, 61, 12, fill);
            let enc = encode_block(&b, Encoding::Sparse);
            let back = decode_block(&enc, Encoding::Sparse).unwrap();
            assert_eq!(b.rows, back.rows, "fill={fill}");
            assert_eq!((b.id, b.lo, b.hi, b.stride), (back.id, back.lo, back.hi, back.stride));
        }
    }

    #[test]
    fn wire_encoding_matches_model_wire() {
        let b = sample_block(3, 0, 17, 8, 0.3);
        assert_eq!(encode_block(&b, Encoding::Wire), crate::model::wire::encode_block(&b));
    }

    #[test]
    fn sparse_beats_wire_on_longtail_blocks() {
        // 1000 words, 2% of (word, topic) cells occupied: most rows empty.
        let b = sample_block(11, 0, 1000, 64, 0.002);
        let sparse = encode_block(&b, Encoding::Sparse).len();
        let wire = encode_block(&b, Encoding::Wire).len();
        assert!(sparse < wire, "sparse={sparse} wire={wire}");
    }

    #[test]
    fn sparse_decode_rejects_truncation_and_garbage() {
        let b = sample_block(5, 0, 40, 16, 0.2);
        let enc = encode_block(&b, Encoding::Sparse);
        for cut in [0, 5, 11, enc.len() / 2, enc.len() - 1] {
            assert!(decode_block(&enc[..cut], Encoding::Sparse).is_err(), "cut={cut}");
        }
        let mut trailing = enc.clone();
        trailing.push(0);
        assert!(decode_block(&trailing, Encoding::Sparse).is_err());
    }

    #[test]
    fn encoding_tag_round_trips() {
        for e in [Encoding::Wire, Encoding::Sparse] {
            assert_eq!(Encoding::from_tag(e.tag()).unwrap(), e);
        }
        assert!(Encoding::from_tag(9).is_err());
    }
}
