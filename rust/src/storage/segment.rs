//! Log-structured segment file: one per shard-home machine.
//!
//! A [`HomeSegment`] is an append-only record log on disk plus an
//! in-memory `block → (offset, len, encoding)` index. Spilling a block
//! appends a record; re-spilling the same block appends a *new* record and
//! marks the old one dead (the index always points at the latest). When
//! dead bytes outgrow live bytes the segment compacts: live records are
//! rewritten to a temp file which atomically renames over the log.
//!
//! Record layout (little-endian):
//! ```text
//! Record := payload_len:u32  block_id:u32  encoding:u8  checksum:u64  payload
//! ```
//! `checksum` is FNV-1a over the payload. On reopen the log is scanned
//! sequentially; the first record that runs past end-of-file or fails its
//! checksum is treated as a torn final append (crash mid-write) and the
//! file is truncated there. Corruption detected on a *read* — the record
//! was fine at scan time — surfaces as the typed
//! [`MpldaError::SegmentCorrupt`] / [`MpldaError::SegmentTruncated`].

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::error::MpldaError;
use crate::storage::codec::Encoding;

/// Fixed per-record header: `len:u32 id:u32 encoding:u8 checksum:u64`.
const HEADER_LEN: u64 = 4 + 4 + 1 + 8;

/// Don't bother compacting segments smaller than this.
const COMPACT_MIN_DEAD: u64 = 4096;

/// FNV-1a 64-bit — dependency-free payload checksum.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[derive(Debug, Clone, Copy)]
struct RecordLoc {
    /// Byte offset of the record header in the file.
    offset: u64,
    /// Payload length in bytes.
    len: u32,
    encoding: Encoding,
}

/// Append-only spill log for one shard-home, with an in-memory index.
#[derive(Debug)]
pub struct HomeSegment {
    path: PathBuf,
    file: File,
    index: BTreeMap<u32, RecordLoc>,
    /// Bytes (header + payload) of records the index still points at.
    live_bytes: u64,
    /// Bytes of superseded/removed records awaiting compaction.
    dead_bytes: u64,
    /// Current append offset (logical end of log).
    end: u64,
}

impl HomeSegment {
    /// Create a fresh, empty segment, truncating any existing file.
    pub fn create(path: &Path) -> Result<HomeSegment> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("creating segment {}", path.display()))?;
        Ok(HomeSegment {
            path: path.to_path_buf(),
            file,
            index: BTreeMap::new(),
            live_bytes: 0,
            dead_bytes: 0,
            end: 0,
        })
    }

    /// Reopen an existing segment, rebuilding the index by sequential scan.
    /// A torn final record (crash mid-append) is detected — it runs past
    /// end-of-file or fails its checksum — logged, and truncated away.
    pub fn open(path: &Path) -> Result<HomeSegment> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("opening segment {}", path.display()))?;
        let file_len = file.metadata()?.len();
        let mut index: BTreeMap<u32, RecordLoc> = BTreeMap::new();
        let mut offset = 0u64;
        let mut dead_bytes = 0u64;
        file.seek(SeekFrom::Start(0))?;
        while offset < file_len {
            let torn = |why: &str| {
                log::warn!(
                    "segment {}: discarding torn tail at offset {offset} ({why})",
                    path.display()
                );
            };
            if file_len - offset < HEADER_LEN {
                torn("partial header");
                break;
            }
            let mut header = [0u8; HEADER_LEN as usize];
            file.read_exact(&mut header)?;
            let len = u32::from_le_bytes(header[0..4].try_into().unwrap());
            let id = u32::from_le_bytes(header[4..8].try_into().unwrap());
            let checksum = u64::from_le_bytes(header[9..17].try_into().unwrap());
            if file_len - offset - HEADER_LEN < len as u64 {
                torn("partial payload");
                break;
            }
            let mut payload = vec![0u8; len as usize];
            file.read_exact(&mut payload)?;
            let Ok(encoding) = Encoding::from_tag(header[8]) else {
                torn("unknown encoding tag");
                break;
            };
            if fnv1a(&payload) != checksum {
                torn("checksum mismatch");
                break;
            }
            if let Some(old) = index.insert(id, RecordLoc { offset, len, encoding }) {
                dead_bytes += HEADER_LEN + old.len as u64;
            }
            offset += HEADER_LEN + len as u64;
        }
        if offset < file_len {
            file.set_len(offset)?;
        }
        let live_bytes = index.values().map(|r| HEADER_LEN + r.len as u64).sum();
        Ok(HomeSegment { path: path.to_path_buf(), file, index, live_bytes, dead_bytes, end: offset })
    }

    /// Append (or supersede) the record for `id`. Compacts afterwards if
    /// dead bytes outweigh live bytes.
    pub fn append(&mut self, id: u32, encoding: Encoding, payload: &[u8]) -> Result<()> {
        let len = payload.len() as u32;
        let mut record = Vec::with_capacity(HEADER_LEN as usize + payload.len());
        record.extend_from_slice(&len.to_le_bytes());
        record.extend_from_slice(&id.to_le_bytes());
        record.push(encoding.tag());
        record.extend_from_slice(&fnv1a(payload).to_le_bytes());
        record.extend_from_slice(payload);
        self.file.seek(SeekFrom::Start(self.end))?;
        self.file
            .write_all(&record)
            .with_context(|| format!("appending block {id} to {}", self.path.display()))?;
        let loc = RecordLoc { offset: self.end, len, encoding };
        self.end += record.len() as u64;
        self.live_bytes += HEADER_LEN + len as u64;
        if let Some(old) = self.index.insert(id, loc) {
            let bytes = HEADER_LEN + old.len as u64;
            self.live_bytes -= bytes;
            self.dead_bytes += bytes;
        }
        self.maybe_compact()
    }

    /// Read back the payload for `id`, verifying the checksum. Returns
    /// `None` if the block is not in this segment; typed
    /// [`MpldaError::SegmentTruncated`] / [`MpldaError::SegmentCorrupt`]
    /// if the record bytes are damaged.
    pub fn read(&mut self, id: u32) -> Result<Option<(Encoding, Vec<u8>)>> {
        let Some(loc) = self.index.get(&id).copied() else {
            return Ok(None);
        };
        self.file.seek(SeekFrom::Start(loc.offset))?;
        let mut record = vec![0u8; HEADER_LEN as usize + loc.len as usize];
        self.file
            .read_exact(&mut record)
            .map_err(|_| MpldaError::SegmentTruncated { offset: loc.offset })?;
        let len = u32::from_le_bytes(record[0..4].try_into().unwrap());
        let rid = u32::from_le_bytes(record[4..8].try_into().unwrap());
        let checksum = u64::from_le_bytes(record[9..17].try_into().unwrap());
        if len != loc.len || rid != id {
            return Err(MpldaError::SegmentCorrupt {
                offset: loc.offset,
                reason: format!("header says block {rid} len {len}, index says block {id} len {}", loc.len),
            }
            .into());
        }
        let payload = record.split_off(HEADER_LEN as usize);
        if fnv1a(&payload) != checksum {
            return Err(MpldaError::SegmentCorrupt {
                offset: loc.offset,
                reason: "payload checksum mismatch".into(),
            }
            .into());
        }
        Ok(Some((loc.encoding, payload)))
    }

    /// Drop `id` from the index (the bytes become dead; reclaimed by the
    /// next compaction). No-op if absent.
    pub fn remove(&mut self, id: u32) -> Result<()> {
        if let Some(old) = self.index.remove(&id) {
            let bytes = HEADER_LEN + old.len as u64;
            self.live_bytes -= bytes;
            self.dead_bytes += bytes;
            self.maybe_compact()?;
        }
        Ok(())
    }

    /// Is `id` currently stored in this segment?
    pub fn contains(&self, id: u32) -> bool {
        self.index.contains_key(&id)
    }

    /// Stored block ids, ascending.
    pub fn block_ids(&self) -> Vec<u32> {
        self.index.keys().copied().collect()
    }

    /// Number of live records.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Bytes of live records (header + payload).
    pub fn live_bytes(&self) -> u64 {
        self.live_bytes
    }

    /// Logical size of the log file.
    pub fn file_bytes(&self) -> u64 {
        self.end
    }

    /// Drop every record and truncate the file (home failover moved the
    /// blocks elsewhere).
    pub fn clear(&mut self) -> Result<()> {
        self.index.clear();
        self.live_bytes = 0;
        self.dead_bytes = 0;
        self.end = 0;
        self.file.set_len(0)?;
        Ok(())
    }

    fn maybe_compact(&mut self) -> Result<()> {
        if self.dead_bytes > self.live_bytes && self.dead_bytes >= COMPACT_MIN_DEAD {
            self.compact()?;
        }
        Ok(())
    }

    /// Rewrite live records to a temp file and atomically rename it over
    /// the log. Record order (ascending block id) is deterministic.
    pub fn compact(&mut self) -> Result<()> {
        let tmp_path = self.path.with_extension("seg.tmp");
        let mut records: Vec<(u32, Encoding, Vec<u8>)> = Vec::with_capacity(self.index.len());
        for id in self.block_ids() {
            let (encoding, payload) = self
                .read(id)?
                .expect("indexed block vanished during compaction");
            records.push((id, encoding, payload));
        }
        {
            let mut tmp = File::create(&tmp_path)
                .with_context(|| format!("creating {}", tmp_path.display()))?;
            for (id, encoding, payload) in &records {
                let mut record = Vec::with_capacity(HEADER_LEN as usize + payload.len());
                record.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                record.extend_from_slice(&id.to_le_bytes());
                record.push(encoding.tag());
                record.extend_from_slice(&fnv1a(payload).to_le_bytes());
                record.extend_from_slice(payload);
                tmp.write_all(&record)?;
            }
            tmp.sync_all().ok();
        }
        std::fs::rename(&tmp_path, &self.path)
            .with_context(|| format!("publishing compacted {}", self.path.display()))?;
        self.file = OpenOptions::new().read(true).write(true).open(&self.path)?;
        self.index.clear();
        self.end = 0;
        self.live_bytes = 0;
        self.dead_bytes = 0;
        for (id, encoding, payload) in &records {
            let loc = RecordLoc { offset: self.end, len: payload.len() as u32, encoding: *encoding };
            self.index.insert(*id, loc);
            self.end += HEADER_LEN + payload.len() as u64;
            self.live_bytes += HEADER_LEN + payload.len() as u64;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_seg(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mplda_seg_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("home-0.seg")
    }

    fn cleanup(path: &Path) {
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn append_read_round_trip() {
        let path = temp_seg("roundtrip");
        let mut seg = HomeSegment::create(&path).unwrap();
        seg.append(3, Encoding::Wire, b"hello").unwrap();
        seg.append(9, Encoding::Sparse, b"").unwrap();
        assert_eq!(seg.read(3).unwrap(), Some((Encoding::Wire, b"hello".to_vec())));
        assert_eq!(seg.read(9).unwrap(), Some((Encoding::Sparse, Vec::new())));
        assert_eq!(seg.read(4).unwrap(), None);
        assert_eq!(seg.block_ids(), vec![3, 9]);
        cleanup(&path);
    }

    #[test]
    fn supersede_marks_dead_and_compaction_reclaims() {
        let path = temp_seg("compact");
        let mut seg = HomeSegment::create(&path).unwrap();
        let big = vec![7u8; 8192];
        seg.append(1, Encoding::Wire, &big).unwrap();
        seg.append(2, Encoding::Wire, b"keep").unwrap();
        let before = seg.file_bytes();
        // Superseding the big record flips dead > live and triggers
        // compaction; the new small record must survive.
        seg.append(1, Encoding::Wire, b"small now").unwrap();
        assert!(seg.file_bytes() < before, "{} !< {before}", seg.file_bytes());
        assert_eq!(seg.read(1).unwrap(), Some((Encoding::Wire, b"small now".to_vec())));
        assert_eq!(seg.read(2).unwrap(), Some((Encoding::Wire, b"keep".to_vec())));
        cleanup(&path);
    }

    #[test]
    fn reopen_rebuilds_index() {
        let path = temp_seg("reopen");
        {
            let mut seg = HomeSegment::create(&path).unwrap();
            seg.append(5, Encoding::Sparse, b"abc").unwrap();
            seg.append(6, Encoding::Wire, b"defg").unwrap();
            seg.append(5, Encoding::Wire, b"newer").unwrap();
        }
        let mut seg = HomeSegment::open(&path).unwrap();
        assert_eq!(seg.len(), 2);
        assert_eq!(seg.read(5).unwrap(), Some((Encoding::Wire, b"newer".to_vec())));
        assert_eq!(seg.read(6).unwrap(), Some((Encoding::Wire, b"defg".to_vec())));
        cleanup(&path);
    }

    #[test]
    fn torn_final_append_discarded_on_reopen() {
        let path = temp_seg("torn");
        {
            let mut seg = HomeSegment::create(&path).unwrap();
            seg.append(1, Encoding::Wire, b"complete record").unwrap();
        }
        // Simulate a crash mid-append: half a header, then half a payload.
        for extra in [&[0xFFu8, 0x00][..], &[64, 0, 0, 0, 2, 0, 0, 0, 0, 1, 2, 3, 4, 5, 6, 7, 8, 42][..]] {
            let good_len = {
                let mut f = OpenOptions::new().append(true).open(&path).unwrap();
                let good = f.metadata().unwrap().len();
                f.write_all(extra).unwrap();
                good
            };
            let mut seg = HomeSegment::open(&path).unwrap();
            assert_eq!(seg.len(), 1, "torn tail must be dropped");
            assert_eq!(seg.read(1).unwrap(), Some((Encoding::Wire, b"complete record".to_vec())));
            assert_eq!(seg.file_bytes(), good_len, "file truncated back to last good record");
            assert_eq!(std::fs::metadata(&path).unwrap().len(), good_len);
        }
        cleanup(&path);
    }

    #[test]
    fn corrupted_payload_yields_typed_error_on_read() {
        let path = temp_seg("corrupt");
        let mut seg = HomeSegment::create(&path).unwrap();
        seg.append(1, Encoding::Wire, b"precious bytes").unwrap();
        // Flip a payload byte behind the segment's back.
        {
            let mut f = OpenOptions::new().write(true).open(&path).unwrap();
            f.seek(SeekFrom::Start(HEADER_LEN + 2)).unwrap();
            f.write_all(b"X").unwrap();
        }
        let err = seg.read(1).unwrap_err();
        match err.downcast_ref::<MpldaError>() {
            Some(MpldaError::SegmentCorrupt { offset: 0, .. }) => {}
            other => panic!("expected SegmentCorrupt at offset 0, got {other:?}"),
        }
        cleanup(&path);
    }

    #[test]
    fn shrunken_file_yields_typed_truncation_on_read() {
        let path = temp_seg("shrunk");
        let mut seg = HomeSegment::create(&path).unwrap();
        seg.append(1, Encoding::Wire, b"soon to vanish").unwrap();
        seg.file.set_len(HEADER_LEN + 3).unwrap();
        let err = seg.read(1).unwrap_err();
        match err.downcast_ref::<MpldaError>() {
            Some(MpldaError::SegmentTruncated { offset: 0 }) => {}
            other => panic!("expected SegmentTruncated at offset 0, got {other:?}"),
        }
        cleanup(&path);
    }

    #[test]
    fn remove_then_clear() {
        let path = temp_seg("remove");
        let mut seg = HomeSegment::create(&path).unwrap();
        seg.append(1, Encoding::Wire, b"a").unwrap();
        seg.append(2, Encoding::Wire, b"b").unwrap();
        seg.remove(1).unwrap();
        assert!(!seg.contains(1));
        assert!(seg.contains(2));
        seg.clear().unwrap();
        assert!(seg.is_empty());
        assert_eq!(seg.file_bytes(), 0);
        cleanup(&path);
    }
}
