//! Out-of-core block storage: the disk tier under the KV-store.
//!
//! ROADMAP item 3 — the paper's 200-billion-variable headline is only
//! reachable when model size stops being bounded by the smallest node's
//! RAM. This module provides the mechanism: each shard-home machine gets a
//! log-structured [`segment::HomeSegment`] file, and the
//! [`KvStore`](crate::kvstore::KvStore) spills cold resident blocks to it
//! whenever the home's resident bytes exceed `storage.resident_budget_mib`,
//! recalling them transparently on the next lease or read.
//!
//! * [`codec`] — block payload encodings: the `model::wire` varint format
//!   verbatim, or a compressed-sparse-row layout whose disk bytes are
//!   proportional to non-zeros (long-tail blocks are mostly empty rows).
//! * [`segment`] — the append-on-commit record log with checksummed
//!   records, torn-tail recovery, and dead-byte compaction.
//!
//! The tier is **transparent**: spill/recall never changes block content
//! (the codecs are lossless), never enters the network model
//! (`TransferKind::{BlockSpill, BlockRecall}` are metered but filtered
//! out of simulated flows), and evicts by a deterministic
//! (last-commit-round, block-id) rule — so a starved run is bitwise-equal
//! (model digest, LL series, served `DocTopics`) to a fully-resident one.
//! DESIGN.md §Storage carries the full argument.

pub mod codec;
pub mod segment;

use std::path::PathBuf;

pub use codec::Encoding;
pub use segment::HomeSegment;

/// Configuration of the disk tier, attached to a `KvStore` via
/// [`KvStore::attach_storage`](crate::kvstore::KvStore::attach_storage).
#[derive(Debug, Clone)]
pub struct StorageOptions {
    /// Directory holding one `home-<m>.seg` per shard-home. Created on
    /// attach; each concurrent run needs its own directory.
    pub dir: PathBuf,
    /// Resident-block byte budget **per shard-home machine**. Commits
    /// that push a home past this spill its coldest blocks to disk.
    pub budget_bytes: u64,
    /// Payload encoding for spilled blocks.
    pub encoding: Encoding,
}
