//! `mplda` — the CLI launcher.
//!
//! ```text
//! mplda train   [--config FILE] [--<section>.<key> VALUE ...]
//! mplda eval    <fig2|fig3|table1|fig4a|fig4b|all> [options]
//! mplda master  [--config FILE ...]             # distributed trainer, master side
//! mplda worker  --connect HOST:PORT             # distributed trainer, worker side
//! mplda metrics --connect HOST:PORT             # scrape Prometheus metrics
//! mplda corpus  [--corpus.preset NAME ...]      # corpus statistics
//! mplda check   [--runtime.artifacts_dir DIR]   # artifact + PJRT smoke
//! ```
//!
//! Every experiment of the paper's §5 is reachable from `mplda eval`; the
//! same drivers back the `cargo bench` targets. Training commands go
//! through the [`mplda::engine::Session`] facade.

use anyhow::{bail, Context, Result};

use mplda::config::Config;
use mplda::engine::{IterEvent, SessionBuilder};
use mplda::eval;
use mplda::util::cli::{Args, HelpBuilder};
use mplda::util::{fmt, logger};

fn main() {
    logger::init();
    let args = Args::from_env(true);
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn load_config(args: &Args) -> Result<Config> {
    // Defaults stay *unresolved* (workers/blocks = 0 sentinels) until after
    // CLI overrides, so `--coord.workers 64` implies blocks = 64 rather
    // than clashing with an eagerly-derived default. When using --config,
    // override coord.blocks explicitly if you also override coord.workers.
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    cfg.apply_overrides(args.options())?;
    Ok(cfg)
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(args),
        Some("eval") => cmd_eval(args),
        Some("corpus") => cmd_corpus(args),
        Some("topics") => cmd_topics(args),
        Some("serve") => cmd_serve(args),
        Some("master") => cmd_master(args),
        Some("worker") => cmd_worker(args),
        Some("metrics") => cmd_metrics(args),
        Some("check") => cmd_check(args),
        Some("help") | None => {
            print!("{}", help());
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?} (try `mplda help`)"),
    }
}

fn help() -> String {
    HelpBuilder::new(&format!(
        "mplda {} — model-parallel inference for big topic models\n\
         (Zheng, Kim, Ho & Xing, 2014 — rust + JAX/Pallas reproduction)",
        mplda::VERSION
    ))
    .section("Commands")
    .entry("train", "train LDA per config (model-parallel or baseline)")
    .entry("eval <exp>", "reproduce a paper experiment: fig2 fig3 table1 fig4a fig4b ablations all")
    .entry("topics", "train briefly, then print top words + coherence per topic")
    .entry("serve", "train, then serve fold-in queries over TCP (block-paged model)")
    .entry("master", "train as the distributed master: listen per [dist], wait for workers")
    .entry("worker --connect A", "join a distributed master at address A (HOST:PORT)")
    .entry("metrics --connect A", "scrape Prometheus metrics from a serve front end or master")
    .entry("corpus", "print corpus statistics for a preset")
    .entry("check", "verify AOT artifacts load and execute via PJRT")
    .section("Common options")
    .entry("--config FILE", "TOML config (see configs/)")
    .entry("--<sec>.<key> V", "override any config key, e.g. --train.topics 1000")
    .entry("--out DIR", "experiment CSV output dir (default out/)")
    .render()
}

/// The standard per-iteration progress line (`baseline` selects the
/// skip-rate format — Δ is meaningless for the data-parallel system).
fn log_progress(baseline: bool, ev: &IterEvent) {
    if let Some(ll) = ev.loglik {
        if baseline {
            log::info!(
                "iter {:3} t={:8.2}s ll={} skip={:.0}%",
                ev.stats.iteration,
                ev.stats.sim_time,
                fmt::sci(ll),
                ev.skip_rate * 100.0
            );
        } else {
            log::info!(
                "iter {:3} t={:8.2}s ll={} Δ={:.2e}",
                ev.stats.iteration,
                ev.stats.sim_time,
                fmt::sci(ll),
                ev.stats.mean_delta
            );
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    if cfg.output.trace {
        return cmd_train_traced(cfg);
    }
    log::info!(
        "training: sampler={} K={} iters={} workers={} machines={}",
        cfg.train.sampler.name(),
        cfg.train.topics,
        cfg.train.iterations,
        cfg.coord.workers,
        cfg.cluster.machines
    );
    let mut session = SessionBuilder::from_config(cfg).build()?;
    let baseline = session.driver().is_none();
    let summary = session.train_observed(|ev| log_progress(baseline, ev))?;
    println!("== training complete ==");
    println!("final log-likelihood : {}", fmt::sci(summary.final_loglik));
    println!("simulated time       : {}", mplda::util::bench::fmt_secs(summary.sim_time));
    println!("tokens sampled       : {}", fmt::count(summary.total_tokens));
    println!("communication        : {}", fmt::bytes(summary.total_comm_bytes));
    println!("peak node memory     : {}", fmt::bytes(summary.peak_mem_bytes));
    if summary.max_delta > 0.0 {
        println!("max Δ_r,i            : {:.3e}", summary.max_delta);
    }
    if summary.host_compute_secs > 0.0 {
        println!(
            "sampler throughput   : {}",
            mplda::util::bench::fmt_rate(
                summary.total_tokens as f64 / summary.host_compute_secs,
                "tok"
            )
        );
    }
    Ok(())
}

/// Traced variant of `train`: runs with the phase timeline on, prints the
/// phase breakdown and writes Chrome trace JSON (model-parallel only —
/// the timeline lives on the driver, reached through the facade's escape
/// hatch).
fn cmd_train_traced(cfg: Config) -> Result<()> {
    use mplda::coordinator::Phase;
    let mut session = SessionBuilder::from_config(cfg.clone()).build()?;
    // Fail before training, not after: the baseline has no driver
    // timeline to trace.
    if session.driver().is_none() {
        bail!(
            "--output.trace records driver phases; the data-parallel baseline ({}) has none",
            cfg.train.sampler.name()
        );
    }
    let summary = session.train()?;
    println!("final log-likelihood : {}", fmt::sci(summary.final_loglik));
    println!("simulated time       : {}", mplda::util::bench::fmt_secs(summary.sim_time));
    let driver = session
        .driver()
        .context("--output.trace records driver phases; the baseline has none")?;
    println!("\nphase breakdown (fraction of worker-time):");
    for phase in [Phase::TotalsSync, Phase::Fetch, Phase::Compute, Phase::Commit, Phase::Barrier]
    {
        println!("  {:12?} {:6.1}%", phase, driver.timeline.phase_fraction(phase) * 100.0);
    }
    std::fs::create_dir_all(&cfg.output.dir)?;
    let path = std::path::Path::new(&cfg.output.dir).join("trace.json");
    driver.timeline.write_chrome_trace(&path)?;
    println!("\nchrome trace written to {path:?} ({} spans)", driver.timeline.spans().len());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let which = args
        .positional()
        .first()
        .map(|s| s.as_str())
        .context("eval needs an experiment: fig2 fig3 table1 fig4a fig4b all")?;
    let out_dir = Some(args.get_or("out", "out"));
    let run_one = |name: &str| -> Result<()> {
        let report = match name {
            "fig2" => {
                eval::fig2::run(&eval::fig2::Opts { out_dir: out_dir.clone(), ..Default::default() })?
            }
            "fig3" => {
                eval::fig3::run(&eval::fig3::Opts { out_dir: out_dir.clone(), ..Default::default() })?
            }
            "table1" => eval::table1::run(&eval::table1::Opts {
                out_dir: out_dir.clone(),
                ..Default::default()
            })?,
            "fig4a" => eval::fig4a::run(&eval::fig4a::Opts {
                out_dir: out_dir.clone(),
                ..Default::default()
            })?,
            "fig4b" => eval::fig4b::run(&eval::fig4b::Opts {
                out_dir: out_dir.clone(),
                ..Default::default()
            })?,
            "ablations" => eval::ablations::run(&eval::ablations::Opts::default())?,
            other => bail!("unknown experiment {other:?}"),
        };
        println!("{report}");
        Ok(())
    };
    if which == "all" {
        for name in ["fig2", "fig3", "table1", "fig4a", "fig4b"] {
            println!("\n##### {name} #####\n");
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}

fn cmd_corpus(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let corpus = mplda::corpus::build(&cfg.corpus)?;
    println!("preset   : {}", cfg.corpus.preset);
    println!("{}", corpus.summary());
    let freqs = corpus.word_frequencies();
    println!("head word freq : {}", freqs.first().copied().unwrap_or(0));
    println!(
        "model variables at K={}: {}",
        cfg.train.topics,
        fmt::count(corpus.model_variables(cfg.train.topics))
    );
    Ok(())
}

/// Train briefly, freeze, and show topic quality: top words and UMass
/// coherence over the frozen model's word–topic table.
fn cmd_topics(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    if cfg.train.iterations > 30 {
        cfg.train.iterations = 30;
    }
    let mut session = SessionBuilder::from_config(cfg).build()?;
    session.train()?;
    let corpus = session.corpus().clone();
    let model = session.freeze()?;
    let n = args.parsed_or("top", 10usize)?;
    for line in mplda::metrics::topics::render_topics(model.word_topic(), &corpus, n) {
        println!("{line}");
    }
    println!(
        "\nmean UMass coherence (top {n}): {:.2}",
        mplda::metrics::topics::mean_coherence(model.word_topic(), &corpus, n)
    );
    Ok(())
}

/// Train per config (optionally resuming a checkpoint), freeze the model
/// **sharded**, and serve fold-in queries over TCP until a `shutdown`
/// request arrives. The model never materializes densely — blocks page
/// through the `serve.cache_budget_mib`-bounded LRU cache on demand.
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let mut builder = SessionBuilder::from_config(cfg.clone());
    if let Some(ckpt) = args.get("resume") {
        builder = builder.resume_from(ckpt);
    }
    let mut session = builder.build()?;
    // Fail before training, not after: serving pages the model-parallel
    // driver's block shards; the baseline has none.
    if session.driver().is_none() {
        bail!(
            "serve rides the model-parallel driver; the data-parallel baseline ({}) holds \
             a full replica — train with sampler = \"inverted-xy\" (or mh-alias)",
            cfg.train.sampler.name()
        );
    }
    if cfg.train.iterations > 0 {
        log::info!(
            "training before serving: sampler={} K={} iters={}",
            cfg.train.sampler.name(),
            cfg.train.topics,
            cfg.train.iterations
        );
        session.train_observed(|ev| log_progress(false, ev))?;
    }
    let model = session.freeze_sharded()?;
    println!(
        "model ready: V={} K={} in {} blocks ({} total)",
        model.num_words(),
        model.num_topics(),
        model.num_blocks(),
        fmt::bytes(model.total_block_bytes()),
    );
    let disk = model.disk_stats();
    if disk.attached {
        println!(
            "out-of-core tier attached: {} spilled (budget {} MiB, dir {}) — `stats` reports \
             disk_recalls / disk_recall_p99_ms",
            fmt::bytes(disk.spill_bytes),
            cfg.storage.resident_budget_mib,
            cfg.storage.dir,
        );
    }
    let server = mplda::serve::Server::serve(model, &cfg.serve)?;
    println!("serving on {}", server.addr());
    println!("protocol: length-prefixed JSON — ping | infer | stats | metrics | shutdown");
    println!("stop with a {{\"type\":\"shutdown\"}} request");
    server.join();
    println!("server stopped");
    Ok(())
}

/// Train as the distributed master: bind the `[dist]` listener, print the
/// address workers should join, then run the normal training loop — the
/// first round blocks until `dist.workers` processes complete the
/// register→init→ready handshake.
fn cmd_master(args: &Args) -> Result<()> {
    use mplda::config::{ExecutionMode, PipelineMode};
    let mut cfg = load_config(args)?;
    cfg.coord.execution = ExecutionMode::Distributed;
    cfg.coord.pipeline = PipelineMode::Off;
    if cfg.dist.workers == 0 {
        cfg.dist.workers = cfg.coord.workers;
    }
    let expected = cfg.dist.workers;
    log::info!(
        "distributed training: sampler={} K={} iters={} positions={} processes={}",
        cfg.train.sampler.name(),
        cfg.train.topics,
        cfg.train.iterations,
        cfg.coord.workers,
        expected
    );
    let mut session = SessionBuilder::from_config(cfg).build()?;
    let addr = session
        .driver()
        .and_then(|d| d.listen_addr())
        .context("distributed driver did not bind a listener")?;
    println!("master listening on {addr}");
    println!("waiting for {expected} worker(s): mplda worker --connect {addr}");
    let summary = session.train_observed(|ev| log_progress(false, ev))?;
    println!("== training complete ==");
    println!("final log-likelihood : {}", fmt::sci(summary.final_loglik));
    println!("simulated time       : {}", mplda::util::bench::fmt_secs(summary.sim_time));
    println!("tokens sampled       : {}", fmt::count(summary.total_tokens));
    Ok(())
}

/// Join a distributed master as a worker process: stateless compute that
/// rebuilds the corpus from the master's recipe and answers sampling
/// tasks until the master shuts the session down.
fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .context("worker needs --connect HOST:PORT (printed by `mplda master`)")?;
    mplda::distributed::worker::run(addr)
}

/// Scrape a running serving front end or distributed master: send one
/// `{"type":"metrics"}` request, validate the returned body as
/// Prometheus text exposition format, and print it to stdout (the
/// validation summary goes to stderr so the output pipes cleanly into
/// other tools).
fn cmd_metrics(args: &Args) -> Result<()> {
    use std::net::ToSocketAddrs;
    let target = args
        .get("connect")
        .context("metrics needs --connect HOST:PORT (a serve front end or a master)")?;
    let addr = target
        .to_socket_addrs()
        .with_context(|| format!("resolving {target}"))?
        .next()
        .with_context(|| format!("{target} resolved to no address"))?;
    let mut client = mplda::serve::Client::connect(addr)?;
    let body = client.metrics()?;
    let summary = mplda::obs::prometheus::parse(&body)
        .context("scraped body is not valid Prometheus text exposition format")?;
    print!("{body}");
    eprintln!("# {target}: {} metric families, {} samples", summary.families, summary.samples);
    Ok(())
}

fn cmd_check(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let reg = mplda::runtime::ArtifactRegistry::load(&cfg.runtime.artifacts_dir)?;
    println!("manifest: {} artifacts", reg.len());
    let topics = reg.available_topics(mplda::runtime::ArtifactKind::Gibbs);
    println!("gibbs K variants: {topics:?}");
    // Compile + execute the smallest gibbs artifact as a smoke test.
    let k = *topics.first().context("no gibbs artifacts")?;
    let params = mplda::sampler::Params::new(k, 1000, 0.1, 0.01);
    let mut exec = mplda::runtime::XlaExecutor::from_registry(&reg, &params, usize::MAX)?;
    use mplda::sampler::xla_dense::MicrobatchExecutor;
    let b = exec.batch_size();
    let ct = vec![0.0f32; b * k];
    let cd = vec![0.0f32; b * k];
    let ck = vec![10.0f32; k];
    let u = vec![0.5f32; b];
    let z = exec.execute(&ct, &cd, &ck, &u)?;
    println!("executed gibbs_b{b}_k{k}: z[0..4] = {:?}", &z[..4.min(z.len())]);
    println!("PJRT round-trip OK");
    Ok(())
}
