//! # mplda — Model-Parallel Inference for Big Topic Models
//!
//! A production-grade reproduction of *Model-Parallel Inference for Big Topic
//! Models* (Zheng, Kim, Ho, Xing — CS.DC 2014): word-partitioned,
//! model-parallel collapsed Gibbs sampling for LDA, with
//!
//! * a **scheduler** that partitions the `V×K` word–topic table into `M`
//!   disjoint word blocks and rotates them across workers (Algorithm 1),
//! * **workers** that fetch model blocks on demand from a distributed
//!   key-value store, sample on an inverted index with the paper's `X+Y`
//!   decomposition (eq. 3), and commit blocks back (Algorithm 2),
//! * a **lazy-sync protocol** for the non-separable topic-totals vector
//!   `C_k` (§3.3) with the paper's `Δ_{r,i}` error metric,
//! * a **Yahoo!LDA-style data-parallel baseline** (full model replica +
//!   background asynchronous synchronization) for head-to-head comparison,
//! * a **discrete-event cluster simulator** (node presets, per-link
//!   bandwidth/latency, shared-uplink congestion) standing in for the
//!   paper's PROBE clusters,
//! * a **threaded execution engine** (`coord.execution = "threaded"`)
//!   that runs each round's disjoint `(worker, block)` tasks on real OS
//!   threads, lock-free by round disjointness, with bitwise-identical
//!   results to the simulated path,
//! * a **pipelined block-prefetch engine**
//!   (`coord.pipeline = "double_buffer"`) that double-buffers model
//!   blocks per worker — KV-store commits and next-round prefetch staging
//!   overlap with sampling, hiding transfer latency while preserving the
//!   bitwise-identical trajectory (DESIGN.md §Pipelining),
//! * a unified **[`sampler::Kernel`] layer** — all five sampler kernels
//!   (dense oracle, SparseLDA, X+Y, LightLDA-style **amortized-O(1)
//!   `mh-alias`** with per-block proposal-table caches, XLA microbatch)
//!   behind one trait with capability-queried execution legality
//!   (DESIGN.md §Samplers), and
//! * an **XLA/PJRT execution backend** whose compute kernel is authored in
//!   JAX/Pallas and AOT-lowered to HLO text at build time (`make artifacts`);
//!   Python never runs on the sampling path, and
//! * a **[`serve`] tier** (`mplda serve`) — model-parallel *online*
//!   inference: a [`serve::ShardedTopicModel`] pages blocks through a
//!   budget-bounded LRU cache straight from the KV-store, a micro-batcher
//!   groups queued documents by block, and a dependency-free TCP front
//!   end answers fold-in queries bitwise identical to offline
//!   [`engine::TopicModel::infer`] (DESIGN.md §Serving), and
//! * an **out-of-core [`storage`] tier** (`[storage]` config section) —
//!   a log-structured spill file per shard-home with checksummed,
//!   compressed-sparse-row block records; the KV-store evicts cold
//!   blocks past `storage.resident_budget_mib` and recalls them on
//!   lease/read, keeping the trajectory bitwise-equal to a fully
//!   resident run (DESIGN.md §Storage), and
//! * a **[`distributed`] trainer** (`mplda master` / `mplda worker`,
//!   `coord.execution = "distributed"`) — real multi-process execution
//!   over TCP: the master owns the schedule, KV-store and iteration loop;
//!   worker processes lease blocks, sample locally and push commits back,
//!   with the model trajectory **bitwise equal** to the simulated
//!   backend's from the same seed (DESIGN.md §Distributed).
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Quick start
//!
//! Everything goes through the [`engine::Session`] facade: a
//! [`engine::SessionBuilder`] validates the whole configuration up
//! front, `train()` streams iteration events, and `freeze()` turns the
//! trained state into a servable [`engine::TopicModel`].
//!
//! ```no_run
//! use mplda::engine::{BowDoc, Execution, Session};
//!
//! let mut session = Session::builder()
//!     .corpus_preset("tiny")
//!     .topics(50)
//!     .iterations(20)
//!     .execution(Execution::Threaded { parallelism: 4 })
//!     .build()
//!     .unwrap();
//! let summary = session.train().unwrap();
//! println!("final log-likelihood: {}", summary.final_loglik);
//!
//! // Serve the trained model: fold in unseen documents.
//! let model = session.freeze().unwrap();
//! let queries = vec![BowDoc::new(vec![0, 1, 2, 2])];
//! let topics = model.infer(&queries).unwrap();
//! println!("top topic of query 0: {:?}", topics.top_topics(0, 1));
//! ```

pub mod util;
pub mod error;
pub mod config;
pub mod corpus;
pub mod model;
pub mod sampler;
pub mod kvstore;
pub mod storage;
pub mod coordinator;
pub mod distributed;
pub mod engine;
pub mod serve;
pub mod cluster;
pub mod baseline;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod eval;

/// Library version, mirrors `Cargo.toml`.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
