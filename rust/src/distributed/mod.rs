//! Real multi-process master/worker training over TCP.
//!
//! The rest of the crate runs the paper's block-rotation protocol inside
//! one process (`cluster::` simulates the machines). This module promotes
//! the shard-home abstraction to actual OS processes: an `mplda master`
//! owns the `RotationSchedule`, the `KvStore` and the iteration loop from
//! `coordinator::driver`, while `mplda worker` peers register over TCP,
//! receive per-round sampling tasks, run their `sampler::Kernel` locally,
//! and push the results back — block leases, commit receipts,
//! `TransferKind` metering and the lease-timeout fault plane all flow
//! through the same driver code paths as the simulated backends.
//!
//! * [`protocol`] — the typed message vocabulary: the JSON control plane
//!   and full-state fallback, plus the binary delta data plane
//!   (`dist.delta`, the default) whose steady-state tasks/results ship
//!   worker-resident state as sparse deltas stamped with a master epoch
//!   (frames via [`crate::serve::wire`]).
//! * [`master`] — [`master::DistributedBackend`], the fourth
//!   [`crate::engine::Backend`]: selected by
//!   `coord.execution = "distributed"`, it leases/commits against the
//!   master's KV-store and delegates the sampling of each
//!   `(position, round)` task to a connected worker process.
//! * [`worker`] — the worker-process main loop behind `mplda worker`:
//!   deterministic compute plus a per-position resident-state cache,
//!   rebuilt from the master's corpus recipe; answers tasks until
//!   shutdown or EOF.
//!
//! **Correctness bar** (DESIGN.md §Distributed): a distributed run's
//! `model_digest` and log-likelihood series are **bitwise equal** to the
//! simulated backend's from the same seed, at any worker-process count —
//! held by `tests/distributed_determinism.rs` at 1, 2 and 4 processes.

pub mod master;
pub mod protocol;
pub mod worker;

pub use master::DistributedBackend;
pub use protocol::{
    require_epoch, BinMsg, InitMsg, Message, PhaseSample, ResultDeltaMsg, ResultMsg, TaskDeltaMsg,
    TaskMsg, WirePhase, ZRowDiff,
};
