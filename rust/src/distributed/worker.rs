//! The worker side: the main loop behind `mplda worker`.
//!
//! A worker is **deterministic compute plus a cache**. Under the default
//! delta protocol each position's shard state — `docs`, assignments,
//! live-order doc–topic entries and the `C_k` snapshot — stays resident
//! here between rounds, stamped with the master's `epoch`. A
//! steady-state task then carries only routing + RNG + the leased block
//! + a sparse `C_k` delta; the reply carries sparse block/`C_k`/
//! assignment deltas back. A full-state task (first contact, or any
//! resend after the master bumped its epoch) re-installs everything and
//! re-stamps the position. A delta task whose epoch does not match the
//! resident stamp is refused with the typed `StaleEpoch` error rather
//! than sampled against a stale base — by protocol the master never
//! sends one, so hitting this means the conversation itself is broken.
//!
//! Nothing the worker retains is authoritative: every reply re-ships
//! each structure the kernel mutated (as deltas against a base the
//! master also holds), so a worker crash loses at most the one round in
//! flight — exactly what the lease-timeout fault plane is built to
//! sacrifice. JSON full-state tasks (`dist.delta = off`) are answered
//! with JSON full-state results, byte-compatible with the PR-7 protocol.
//!
//! The only worker-local input is the corpus, rebuilt from the master's
//! recipe (`InitMsg::corpus` is seed-deterministic) and verified against
//! the master's fingerprint during the handshake — a config drift between
//! the two processes fails loudly before any sampling happens.

use std::collections::HashMap;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::SamplerKind;
use crate::coordinator::worker::WorkerState;
use crate::corpus;
use crate::model::checkpoint::corpus_fingerprint;
use crate::model::{wire as codec, DocTopic, DocView, ModelBlock, SparseCounts};
use crate::sampler::{cpu_kernel, KernelOpts, Params};
use crate::serve::wire::{
    read_frame, read_frame_any, write_binary_frame, write_frame, write_frame_with_cap, Frame,
    MAX_FRAME,
};
use crate::util::rng::Pcg64;

use super::protocol::{
    require_epoch, z_row_diff, BinMsg, Message, PhaseSample, ResultDeltaMsg, ResultMsg,
    TaskDeltaMsg, TaskMsg, WirePhase,
};

/// Measures this worker's phases for one traced task (decode → sample →
/// encode) as µs offsets from task receipt, for piggybacking on the
/// result frame. Inert when the task did not set `trace`: `begin`
/// returns `None` without reading the clock, so untraced rounds pay
/// nothing. Timings never feed the kernel, the RNG streams or
/// `host_secs` — they are observability-only.
struct PhaseClock {
    t0: Instant,
    on: bool,
    phases: Vec<PhaseSample>,
}

impl PhaseClock {
    fn new(on: bool) -> PhaseClock {
        PhaseClock::with_anchor(Instant::now(), on)
    }

    /// Anchor offsets at `t0` (the moment the task frame was received).
    fn with_anchor(t0: Instant, on: bool) -> PhaseClock {
        PhaseClock { t0, on, phases: Vec::new() }
    }

    fn begin(&self) -> Option<u64> {
        if self.on {
            Some(self.t0.elapsed().as_micros() as u64)
        } else {
            None
        }
    }

    fn end(&mut self, started: Option<u64>, phase: WirePhase) {
        let Some(start_us) = started else { return };
        let end_us = self.t0.elapsed().as_micros() as u64;
        self.phases.push(PhaseSample {
            phase,
            start_us,
            dur_us: end_us.saturating_sub(start_us),
        });
    }

    fn take(&mut self) -> Vec<PhaseSample> {
        std::mem::take(&mut self.phases)
    }
}

/// How long `connect` retries before giving up (the master may not have
/// bound its listener yet when workers launch).
const CONNECT_WAIT: Duration = Duration::from_secs(30);

/// Connect to `addr`, retrying while the master comes up.
fn connect_with_retry(addr: &str) -> Result<TcpStream> {
    let deadline = Instant::now() + CONNECT_WAIT;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() > deadline {
                    return Err(e).with_context(|| format!("connecting to master at {addr:?}"));
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Everything the task loop needs besides the stream: the rebuilt world
/// plus the per-position resident state.
struct WorkerEnv {
    corpus: corpus::Corpus,
    params: Params,
    opts: KernelOpts,
    sampler: SamplerKind,
    num_topics: usize,
    /// Full-corpus-shaped views; tasks splice their shard's rows in by
    /// global doc id, mirroring the master's layout so the kernel sees
    /// identical indices. Under the delta protocol the spliced rows stay
    /// resident between rounds.
    z: Vec<Vec<u32>>,
    dt: DocTopic,
    /// Per-position sampling state (inverted index, RNG, `C_k`).
    cache: HashMap<usize, WorkerState>,
    /// Per-position epoch stamp: which master epoch the resident shard
    /// state belongs to. Delta tasks must match it exactly.
    resident: HashMap<usize, u64>,
}

/// Run the worker loop: register with the master at `addr`, rebuild the
/// corpus from its recipe, then answer sampling tasks until a shutdown
/// frame or a clean EOF. Returns when the master is done with us.
pub fn run(addr: &str) -> Result<()> {
    let mut stream = connect_with_retry(addr)?;
    stream.set_nodelay(true).context("configuring master socket")?;
    write_frame(&mut stream, &Message::Register.to_json())?;

    let init = match read_frame(&mut stream)? {
        Some(j) => match Message::from_json(&j)? {
            Message::Init(init) => init,
            other => bail!("expected init from master, got {:?}", other.kind()),
        },
        None => bail!("master closed the connection before init"),
    };
    let corpus = corpus::build(&init.corpus).context("rebuilding corpus from master recipe")?;
    let fp = corpus_fingerprint(&corpus);
    if fp != init.corpus_fp {
        bail!(
            "rebuilt corpus fingerprint {fp:#x} does not match master's {:#x} — \
             config drift between processes",
            init.corpus_fp
        );
    }
    write_frame(&mut stream, &Message::Ready { corpus_fp: fp }.to_json())?;
    log::info!(
        "worker: registered with {addr}, corpus {} docs / {} words, sampler {}",
        corpus.num_docs(),
        corpus.num_words(),
        init.sampler.name()
    );

    // The data-plane frame cap comes from the master (dist.max_frame_mib);
    // the handshake above always fits the compiled-in default.
    let cap = usize::try_from(init.max_frame_bytes).unwrap_or(MAX_FRAME).max(1 << 16);
    let mut env = WorkerEnv {
        params: Params::new(init.topics, corpus.num_words(), init.alpha, init.beta),
        opts: KernelOpts { alias_budget_bytes: init.alias_budget_bytes },
        sampler: init.sampler,
        num_topics: init.topics,
        z: vec![Vec::new(); corpus.num_docs()],
        dt: DocTopic::zeros(corpus.num_docs()),
        cache: HashMap::new(),
        resident: HashMap::new(),
        corpus,
    };

    loop {
        match read_frame_any(&mut stream, cap)? {
            None => return Ok(()), // master gone; a crash there is its problem
            Some((Frame::Json(j), _)) => match Message::from_json(&j)? {
                Message::Task(task) => {
                    let mut clock = PhaseClock::new(task.trace);
                    let mut reply = run_task(&task, &mut env, &mut clock)?;
                    reply.phases = clock.take();
                    write_frame_with_cap(&mut stream, &Message::Result(reply).to_json(), cap)?;
                }
                Message::Shutdown => {
                    let _ = write_frame(&mut stream, &Message::Bye.to_json());
                    return Ok(());
                }
                other => bail!("expected task or shutdown, got {:?}", other.kind()),
            },
            Some((Frame::Binary(body), _)) => {
                let t_recv = Instant::now();
                let msg = BinMsg::decode(&body).context("decoding binary task")?;
                let frame_us = t_recv.elapsed().as_micros() as u64;
                let trace = match &msg {
                    BinMsg::TaskFull(t) => t.trace,
                    BinMsg::TaskDelta(t) => t.trace,
                    BinMsg::ResultDelta(_) => false,
                };
                let mut clock = PhaseClock::with_anchor(t_recv, trace);
                if trace {
                    clock.phases.push(PhaseSample {
                        phase: WirePhase::Decode,
                        start_us: 0,
                        dur_us: frame_us,
                    });
                }
                let mut reply = match msg {
                    BinMsg::TaskFull(task) => run_task_full(&task, &mut env, &mut clock)?,
                    BinMsg::TaskDelta(task) => run_task_delta(&task, &mut env, &mut clock)?,
                    BinMsg::ResultDelta(_) => bail!("master sent a result frame to a worker"),
                };
                reply.phases = clock.take();
                write_binary_frame(&mut stream, &BinMsg::ResultDelta(reply).encode(), cap)?;
            }
        }
    }
}

/// Validate a full task's shape against the corpus, (re)build the
/// position's sampling state, and splice the shipped shard in.
fn install_full_task(task: &TaskMsg, env: &mut WorkerEnv) -> Result<()> {
    if task.z.len() != task.docs.len() || task.dt.len() != task.docs.len() {
        bail!(
            "task for position {} ships {} z rows / {} dt rows for {} docs",
            task.position,
            task.z.len(),
            task.dt.len(),
            task.docs.len()
        );
    }
    if let Some(&bad) = task.docs.iter().find(|&&d| d as usize >= env.corpus.num_docs()) {
        bail!("task references doc {bad}, corpus has {}", env.corpus.num_docs());
    }
    let ck = codec::decode_totals(&task.ck).context("decoding task C_k")?;

    // Reuse the cached shard state (inverted index) when the doc list is
    // unchanged; rebuild after reassignments. RNG and C_k are overwritten
    // from the task either way.
    let rebuild = match env.cache.get(&task.position) {
        Some(w) => w.docs != task.docs,
        None => true,
    };
    if rebuild {
        env.cache.insert(
            task.position,
            WorkerState::new(task.position, 0, task.docs.clone(), &env.corpus, env.num_topics, 0),
        );
    }
    let ws = env.cache.get_mut(&task.position).unwrap();
    ws.rng = Pcg64::from_raw(task.rng.0, task.rng.1);
    ws.install_totals(ck);

    for ((&d, z_row), dt_row) in task.docs.iter().zip(&task.z).zip(&task.dt) {
        env.z[d as usize] = z_row.clone();
        *env.dt.doc_mut(d as usize) = SparseCounts::from_ordered_entries(dt_row.clone());
    }
    env.resident.insert(task.position, task.epoch);
    Ok(())
}

/// Run one round over the position's resident state and package every
/// mutation as a delta against the pre-round base (which the master
/// holds too).
fn run_resident_round(
    position: usize,
    epoch: u64,
    block: &mut ModelBlock,
    env: &mut WorkerEnv,
    clock: &mut PhaseClock,
) -> Result<ResultDeltaMsg> {
    let ws = env
        .cache
        .get_mut(&position)
        .with_context(|| format!("no resident state for position {position}"))?;
    let z_base: Vec<Vec<u32>> = ws.docs.iter().map(|&d| env.z[d as usize].clone()).collect();
    let ck_base = ws.ck.clone();
    let block_base = block.clone();

    let mut kernel = cpu_kernel(env.sampler, &env.opts)?;
    let t_sample = clock.begin();
    let (tokens, host_secs) = {
        let mut docs = DocView::new(&mut env.z, &mut env.dt);
        ws.run_round(&env.corpus, &mut docs, block, &env.params, &mut *kernel)?
    };
    clock.end(t_sample, WirePhase::Sample);

    let t_encode = clock.begin();
    let z = ws
        .docs
        .iter()
        .zip(&z_base)
        .map(|(&d, base)| z_row_diff(base, &env.z[d as usize]))
        .collect();
    let dt = ws.docs.iter().map(|&d| env.dt.doc(d as usize).iter().collect()).collect();
    let block_delta = codec::encode_block_delta(&block_base, block);
    let ck_delta = codec::encode_totals_delta(&ck_base, &ws.ck);
    clock.end(t_encode, WirePhase::Encode);
    Ok(ResultDeltaMsg {
        position,
        epoch,
        tokens,
        host_secs,
        rng: ws.rng.to_raw(),
        block_delta,
        ck_delta,
        z,
        dt,
        phases: Vec::new(), // the task loop attaches the clock's samples
    })
}

/// Binary full-state task: install everything, stamp the epoch, sample,
/// reply with deltas.
fn run_task_full(
    task: &TaskMsg,
    env: &mut WorkerEnv,
    clock: &mut PhaseClock,
) -> Result<ResultDeltaMsg> {
    install_full_task(task, env)?;
    let t_decode = clock.begin();
    let mut block = codec::decode_block(&task.block).context("decoding task block")?;
    clock.end(t_decode, WirePhase::Decode);
    run_resident_round(task.position, task.epoch, &mut block, env, clock)
}

/// Binary delta task: verify the epoch stamp, patch the resident `C_k`,
/// sample over the resident shard, reply with deltas.
fn run_task_delta(
    task: &TaskDeltaMsg,
    env: &mut WorkerEnv,
    clock: &mut PhaseClock,
) -> Result<ResultDeltaMsg> {
    require_epoch(task.position, task.epoch, env.resident.get(&task.position).copied())?;
    let t_decode = clock.begin();
    let mut block = codec::decode_block(&task.block).context("decoding task block")?;
    clock.end(t_decode, WirePhase::Decode);
    {
        let ws = env
            .cache
            .get_mut(&task.position)
            .with_context(|| format!("no resident state for position {}", task.position))?;
        ws.rng = Pcg64::from_raw(task.rng.0, task.rng.1);
        codec::apply_totals_delta(&mut ws.ck, &task.ck_delta)
            .context("applying task C_k delta")?;
        ws.ck_read = ws.ck.clone();
    }
    run_resident_round(task.position, task.epoch, &mut block, env, clock)
}

/// Execute one JSON full-state task (`dist.delta = off`) and package the
/// full-state reply — the PR-7 protocol, byte for byte plus the epoch
/// echo.
fn run_task(task: &TaskMsg, env: &mut WorkerEnv, clock: &mut PhaseClock) -> Result<ResultMsg> {
    install_full_task(task, env)?;
    let t_decode = clock.begin();
    let mut block = codec::decode_block(&task.block).context("decoding task block")?;
    clock.end(t_decode, WirePhase::Decode);
    let ws = env.cache.get_mut(&task.position).unwrap();

    let mut kernel = cpu_kernel(env.sampler, &env.opts)?;
    let t_sample = clock.begin();
    let (tokens, host_secs) = {
        let mut docs = DocView::new(&mut env.z, &mut env.dt);
        ws.run_round(&env.corpus, &mut docs, &mut block, &env.params, &mut *kernel)?
    };
    clock.end(t_sample, WirePhase::Sample);

    let t_encode = clock.begin();
    let z_out = ws.docs.iter().map(|&d| env.z[d as usize].clone()).collect();
    let dt_out = ws.docs.iter().map(|&d| env.dt.doc(d as usize).iter().collect()).collect();
    let block_bytes = codec::encode_block(&block);
    let ck_bytes = codec::encode_totals(&ws.ck);
    clock.end(t_encode, WirePhase::Encode);
    Ok(ResultMsg {
        position: task.position,
        epoch: task.epoch,
        tokens,
        host_secs,
        block: block_bytes,
        ck: ck_bytes,
        rng: ws.rng.to_raw(),
        z: z_out,
        dt: dt_out,
        phases: Vec::new(), // the task loop attaches the clock's samples
    })
}
