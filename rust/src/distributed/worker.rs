//! The worker side: the main loop behind `mplda worker`.
//!
//! A worker is **stateless compute**: every task ships the complete
//! working set for one `(position, round)` cell — leased block, `C_k`
//! snapshot, RNG stream position, assignments, live-order doc–topic
//! entries — and the reply ships every mutated structure back. Nothing
//! the worker retains between tasks affects the model trajectory; the
//! cache below merely avoids rebuilding the inverted index when the same
//! shard comes back next round (after a rotation reassignment the doc
//! list changes and the cached entry is rebuilt).
//!
//! The only worker-local input is the corpus, rebuilt from the master's
//! recipe (`InitMsg::corpus` is seed-deterministic) and verified against
//! the master's fingerprint during the handshake — a config drift between
//! the two processes fails loudly before any sampling happens.

use std::collections::HashMap;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::SamplerKind;
use crate::coordinator::worker::WorkerState;
use crate::corpus;
use crate::model::checkpoint::corpus_fingerprint;
use crate::model::{wire as codec, DocTopic, DocView, SparseCounts};
use crate::sampler::{cpu_kernel, KernelOpts, Params};
use crate::serve::wire::{read_frame, write_frame};
use crate::util::rng::Pcg64;

use super::protocol::{Message, ResultMsg, TaskMsg};

/// How long `connect` retries before giving up (the master may not have
/// bound its listener yet when workers launch).
const CONNECT_WAIT: Duration = Duration::from_secs(30);

/// Connect to `addr`, retrying while the master comes up.
fn connect_with_retry(addr: &str) -> Result<TcpStream> {
    let deadline = Instant::now() + CONNECT_WAIT;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() > deadline {
                    return Err(e).with_context(|| format!("connecting to master at {addr:?}"));
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Run the worker loop: register with the master at `addr`, rebuild the
/// corpus from its recipe, then answer sampling tasks until a shutdown
/// frame or a clean EOF. Returns when the master is done with us.
pub fn run(addr: &str) -> Result<()> {
    let mut stream = connect_with_retry(addr)?;
    stream.set_nodelay(true).context("configuring master socket")?;
    write_frame(&mut stream, &Message::Register.to_json())?;

    let init = match read_frame(&mut stream)? {
        Some(j) => match Message::from_json(&j)? {
            Message::Init(init) => init,
            other => bail!("expected init from master, got {:?}", other.kind()),
        },
        None => bail!("master closed the connection before init"),
    };
    let corpus = corpus::build(&init.corpus).context("rebuilding corpus from master recipe")?;
    let fp = corpus_fingerprint(&corpus);
    if fp != init.corpus_fp {
        bail!(
            "rebuilt corpus fingerprint {fp:#x} does not match master's {:#x} — \
             config drift between processes",
            init.corpus_fp
        );
    }
    write_frame(&mut stream, &Message::Ready { corpus_fp: fp }.to_json())?;
    log::info!(
        "worker: registered with {addr}, corpus {} docs / {} words, sampler {}",
        corpus.num_docs(),
        corpus.num_words(),
        init.sampler.name()
    );

    let params = Params::new(init.topics, corpus.num_words(), init.alpha, init.beta);
    let opts = KernelOpts { alias_budget_bytes: init.alias_budget_bytes };
    // Full-corpus-shaped views; tasks splice their shard's rows in and
    // out by global doc id, mirroring the master's layout so the kernel
    // sees identical indices.
    let mut z: Vec<Vec<u32>> = vec![Vec::new(); corpus.num_docs()];
    let mut dt = DocTopic::zeros(corpus.num_docs());
    let mut cache: HashMap<usize, WorkerState> = HashMap::new();

    loop {
        let task = match read_frame(&mut stream)? {
            Some(j) => match Message::from_json(&j)? {
                Message::Task(task) => task,
                Message::Shutdown => {
                    let _ = write_frame(&mut stream, &Message::Bye.to_json());
                    return Ok(());
                }
                other => bail!("expected task or shutdown, got {:?}", other.kind()),
            },
            None => return Ok(()), // master gone; a crash there is its problem
        };
        let reply = run_task(
            &task,
            &corpus,
            &params,
            &opts,
            init.sampler,
            init.topics,
            &mut z,
            &mut dt,
            &mut cache,
        )?;
        write_frame(&mut stream, &Message::Result(reply).to_json())?;
    }
}

/// Execute one task against the shipped state and package the reply.
#[allow(clippy::too_many_arguments)]
fn run_task(
    task: &TaskMsg,
    corpus: &corpus::Corpus,
    params: &Params,
    opts: &KernelOpts,
    sampler: SamplerKind,
    num_topics: usize,
    z: &mut [Vec<u32>],
    dt: &mut DocTopic,
    cache: &mut HashMap<usize, WorkerState>,
) -> Result<ResultMsg> {
    if task.z.len() != task.docs.len() || task.dt.len() != task.docs.len() {
        bail!(
            "task for position {} ships {} z rows / {} dt rows for {} docs",
            task.position,
            task.z.len(),
            task.dt.len(),
            task.docs.len()
        );
    }
    if let Some(&bad) = task.docs.iter().find(|&&d| d as usize >= corpus.num_docs()) {
        bail!("task references doc {bad}, corpus has {}", corpus.num_docs());
    }
    let mut block = codec::decode_block(&task.block).context("decoding task block")?;
    let ck = codec::decode_totals(&task.ck).context("decoding task C_k")?;

    // Reuse the cached shard state (inverted index) when the doc list is
    // unchanged; rebuild after reassignments. RNG and C_k are overwritten
    // from the task either way — the cache is a pure index cache.
    let rebuild = match cache.get(&task.position) {
        Some(w) => w.docs != task.docs,
        None => true,
    };
    if rebuild {
        cache.insert(
            task.position,
            WorkerState::new(task.position, 0, task.docs.clone(), corpus, num_topics, 0),
        );
    }
    let ws = cache.get_mut(&task.position).unwrap();
    ws.rng = Pcg64::from_raw(task.rng.0, task.rng.1);
    ws.install_totals(ck);

    for ((&d, z_row), dt_row) in task.docs.iter().zip(&task.z).zip(&task.dt) {
        z[d as usize] = z_row.clone();
        *dt.doc_mut(d as usize) = SparseCounts::from_ordered_entries(dt_row.clone());
    }

    let mut kernel = cpu_kernel(sampler, opts)?;
    let (tokens, host_secs) = {
        let mut docs = DocView::new(z, dt);
        ws.run_round(corpus, &mut docs, &mut block, params, &mut *kernel)?
    };

    let z_out = task.docs.iter().map(|&d| z[d as usize].clone()).collect();
    let dt_out = task.docs.iter().map(|&d| dt.doc(d as usize).iter().collect()).collect();
    Ok(ResultMsg {
        position: task.position,
        tokens,
        host_secs,
        block: codec::encode_block(&block),
        ck: codec::encode_totals(&ws.ck),
        rng: ws.rng.to_raw(),
        z: z_out,
        dt: dt_out,
    })
}
