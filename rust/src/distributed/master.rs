//! The master side: a [`Backend`] whose compute phase happens in other
//! processes.
//!
//! ## Determinism argument (summarized in DESIGN.md §Distributed)
//!
//! The backend contract fixes everything except *where* the sampling
//! kernel runs. This backend keeps the lease phase, the commit phase and
//! the `C_k` merge order byte-identical to [`SimulatedBackend`]'s
//! (`lease_blocks_sync` + the worker-ordered commit loop below); the
//! sampling phase hands each position's working set to a worker process,
//! which runs the *same* `WorkerState::run_round` lifecycle on the
//! *same* bytes and ships every mutated structure back. Nothing about
//! the computation depends on which process hosts it — or on **how the
//! bytes travelled**: with `dist.delta = on` (the default) the working
//! set rides as binary frames and sparse deltas against worker-resident
//! state, with `off` as full-state JSON, and both reconstruct the exact
//! same structures on each side (the delta codecs are lossless and the
//! doc–topic live order ships verbatim either way). So the model
//! trajectory is bitwise equal to the simulated one from the same seed;
//! only wall-clock measurements (which never touch model state) differ.
//!
//! ## Delta protocol and epochs
//!
//! With deltas on, a worker keeps each position's shard state (`docs`,
//! `z`, `dt`) and `C_k` snapshot resident between rounds, and the master
//! mirrors that residency here: `resident[i]` records the epoch at which
//! position `i`'s state last landed on its worker, `resident_ck[i]` the
//! exact `C_k` the worker holds. A steady-state task then carries only
//! routing + RNG + the leased block (rotation hands out a different
//! block every round — there is no base to delta against) + a sparse
//! `C_k` delta; the reply carries sparse block/`C_k`/assignment deltas
//! plus the tiny live-order doc–topic rows. The master bumps its
//! `epoch` on *any* event that could desynchronize a resident — a
//! connection lost (positions re-deal over the survivors), a shard's
//! doc list changed (rotation reassignment / adoption), a driver-side
//! mutation signalled through [`Backend::invalidate_worker_cache`]
//! (degraded rounds), a checkpoint restore (`reset_workers`) — after
//! which every position's next task ships full again. Over-bumping
//! costs one full resend and nothing else, which is what makes the
//! fault path safe by construction.
//!
//! Task/result frame bytes are metered out-of-band
//! (`TransferKind::{TaskDelta,TaskFull,ResultDelta,ResultFull}`): they
//! are real TCP traffic worth measuring (E13), but the simulated
//! network model already accounts the *logical* transfers (block
//! fetch/commit, totals sync) — double-charging them would diverge
//! `sim_time`/`comm_bytes` from the oracle.
//!
//! ## Fault path
//!
//! A worker process that dies mid-round takes its socket with it; the
//! send or receive for its positions fails and those positions come back
//! in [`RoundOutcome::dead`]. Their leases are already out (taken in the
//! lease phase) and stay uncommitted — exactly the state a scripted
//! `kill@` fault leaves — so the driver's PR-6 machinery (grace rounds,
//! lease revocation from the recovery copy, rotation reassignment, shard
//! adoption) handles the rest without knowing sockets exist. The corpse
//! held nothing the master lacks (results re-ship every mutated
//! structure each round, delta or not), and the roster change bumps the
//! epoch so every survivor's next task is a full resend.
//!
//! [`SimulatedBackend`]: crate::engine::backend::SimulatedBackend

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cluster::{Flow, MemCategory};
use crate::config::{Config, SamplerKind};
use crate::engine::backend::{lease_blocks_sync, Backend, RoundCtx, RoundOutcome};
use crate::kvstore::traffic::TransferKind;
use crate::model::checkpoint::corpus_fingerprint;
use crate::model::{wire as codec, SparseCounts, TopicCounts};
use crate::obs::trace::{tid_worker, TID_DRIVER};
use crate::obs::{self, names, Log2Histogram, TraceEvent, Tracer};
use crate::serve::json::Json;
use crate::serve::wire::{
    read_frame, read_frame_any, write_binary_frame, write_frame, write_frame_with_cap, Frame,
};
use crate::util::rng::Pcg64;

use super::protocol::{
    apply_z_row_diff, require_epoch, BinMsg, InitMsg, Message, PhaseSample, ResultDeltaMsg,
    ResultMsg, TaskDeltaMsg, TaskMsg,
};

/// How long the first round waits for the full worker roster to connect
/// and complete the handshake before giving up.
const HANDSHAKE_WAIT: Duration = Duration::from_secs(120);

/// One registered worker process.
struct WorkerConn {
    stream: TcpStream,
}

impl WorkerConn {
    /// Control-plane send (handshake/shutdown): JSON at the default cap.
    fn send(&mut self, msg: &Message) -> Result<()> {
        write_frame(&mut self.stream, &msg.to_json())
    }

    /// Data-plane JSON send (`dist.delta = off` tasks); returns frame
    /// bytes for the transport meter.
    fn send_json(&mut self, msg: &Message, cap: usize) -> Result<u64> {
        write_frame_with_cap(&mut self.stream, &msg.to_json(), cap)
    }

    /// Data-plane binary send; returns frame bytes.
    fn send_bin(&mut self, msg: &BinMsg, cap: usize) -> Result<u64> {
        write_binary_frame(&mut self.stream, &msg.encode(), cap)
    }

    /// Control-plane receive: JSON only, default cap.
    fn recv(&mut self) -> Result<Message> {
        match read_frame(&mut self.stream)? {
            Some(j) => Message::from_json(&j),
            None => bail!("worker closed its connection"),
        }
    }

    /// Data-plane receive: either frame flavor, decoded, with its wire
    /// byte count.
    fn recv_any(&mut self, cap: usize) -> Result<(AnyMsg, u64)> {
        match read_frame_any(&mut self.stream, cap)? {
            Some((Frame::Json(j), bytes)) => Ok((AnyMsg::Json(Message::from_json(&j)?), bytes)),
            Some((Frame::Binary(body), bytes)) => {
                Ok((AnyMsg::Bin(BinMsg::decode(&body)?), bytes))
            }
            None => bail!("worker closed its connection"),
        }
    }
}

/// A decoded data-plane frame from a worker.
enum AnyMsg {
    Json(Message),
    Bin(BinMsg),
}

/// One position's reply for the round, in whichever encoding it arrived.
enum RoundResult {
    Full(ResultMsg),
    Delta(ResultDeltaMsg),
}

/// The `coord.execution = "distributed"` backend: master-side transport
/// plus the lease/commit halves of the round. Binds its listener eagerly
/// at construction (so `Driver::listen_addr` is known before training
/// starts) and completes the worker handshake lazily on the first round
/// (the corpus fingerprint it must verify lives on the driver).
pub struct DistributedBackend {
    listener: TcpListener,
    addr: SocketAddr,
    expected: usize,
    io_timeout: Option<Duration>,
    init: InitMsg,
    conns: Vec<WorkerConn>,
    handshook: bool,
    /// `dist.delta`: binary delta protocol on the hot path.
    delta: bool,
    /// `dist.max_frame_mib`, in bytes; data-plane frame cap both ways.
    max_frame: usize,
    /// Current delta-protocol epoch; bumped whenever worker residency
    /// may be stale, which forces full resends.
    epoch: u64,
    /// A residency-invalidating event happened since the last round
    /// (roster change, driver-side mutation, restore).
    stale: bool,
    /// Per position: the epoch at which its state last became resident
    /// on its worker, if it is resident at all.
    resident: Vec<Option<u64>>,
    /// Per position: the exact `C_k` snapshot the worker holds (base
    /// for the next task's `C_k` delta).
    resident_ck: Vec<Option<TopicCounts>>,
    /// Per position: the doc list last seen, to detect reassignments.
    resident_docs: Vec<Vec<u32>>,
    /// The shared metrics registry, when the driver attached one
    /// ([`Backend::attach_obs`]); also serves the listener's `metrics`
    /// scrape verb.
    registry: Option<Arc<obs::Registry>>,
    /// Master wait from the start of each result-collection wave to
    /// each result's arrival (µs) — the straggler picture.
    round_wait: Log2Histogram,
}

impl DistributedBackend {
    /// Bind the listen address from `cfg.dist` and capture the handshake
    /// payload. No worker needs to be running yet.
    pub fn new(cfg: &Config) -> Result<DistributedBackend> {
        if cfg.dist.workers == 0 {
            bail!("dist.workers must be >= 1 (finalize() resolves 0 to coord.workers)");
        }
        let listener = TcpListener::bind(&cfg.dist.listen)
            .with_context(|| format!("binding master listener on {:?}", cfg.dist.listen))?;
        let addr = listener.local_addr().context("reading master listen address")?;
        let io_timeout = if cfg.dist.io_timeout_secs > 0.0 {
            Some(Duration::from_secs_f64(cfg.dist.io_timeout_secs))
        } else {
            None
        };
        let max_frame = cfg.dist.max_frame_mib.saturating_mul(1 << 20);
        let init = InitMsg {
            corpus: cfg.corpus.clone(),
            topics: cfg.train.topics,
            alpha: cfg.train.alpha,
            beta: cfg.train.beta,
            sampler: cfg.train.sampler,
            alias_budget_bytes: (cfg.train.alias_budget_mib * (1u64 << 20) as f64).round() as u64,
            corpus_fp: 0, // filled at handshake, when the corpus exists
            max_frame_bytes: max_frame as u64,
        };
        Ok(DistributedBackend {
            listener,
            addr,
            expected: cfg.dist.workers,
            io_timeout,
            init,
            conns: Vec::new(),
            handshook: false,
            delta: cfg.dist.delta,
            max_frame,
            epoch: 0,
            stale: true,
            resident: Vec::new(),
            resident_ck: Vec::new(),
            resident_docs: Vec::new(),
            registry: None,
            round_wait: Log2Histogram::new(),
        })
    }

    /// Answer any pending connections on the listen socket with the
    /// serve-tier `metrics` verb (one request/reply per connection).
    /// After the worker handshake completes the listener has no other
    /// callers, so everything that connects now is a scrape; the poll is
    /// non-blocking and costs one `accept` syscall per round when nobody
    /// is scraping. Scrape failures are logged, never fatal — a broken
    /// monitoring client must not kill training.
    fn poll_scrapes(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((mut stream, _peer)) => {
                    let body = match self.registry.as_ref() {
                        Some(reg) => reg.render_prometheus(),
                        None => String::new(),
                    };
                    if let Err(e) = serve_scrape(&mut stream, &body) {
                        log::warn!("distributed: metrics scrape failed: {e:#}");
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) => {
                    log::warn!("distributed: metrics listener error: {e:#}");
                    return;
                }
            }
        }
    }

    /// Mirror the transport's own statistics into the registry.
    fn export_metrics(&self) {
        let Some(reg) = self.registry.as_ref() else { return };
        reg.set_histogram(
            names::DIST_ROUND_WAIT,
            "Master wait from wave start to each result's arrival.",
            &[],
            &self.round_wait,
        );
        reg.set_gauge(
            names::DIST_WORKERS,
            "Worker processes currently connected.",
            &[],
            self.conns.len() as f64,
        );
        reg.set_gauge(
            names::DIST_EPOCH,
            "Delta-protocol epoch (counts full-resend generations).",
            &[],
            self.epoch as f64,
        );
    }

    /// Accept `expected` connections and run the register→init→ready
    /// handshake on each, verifying every worker rebuilt the identical
    /// corpus.
    fn handshake(&mut self, corpus_fp: u64) -> Result<()> {
        self.init.corpus_fp = corpus_fp;
        self.listener
            .set_nonblocking(true)
            .context("switching master listener to polling")?;
        let deadline = Instant::now() + HANDSHAKE_WAIT;
        while self.conns.len() < self.expected {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    stream.set_nonblocking(false).context("configuring worker socket")?;
                    stream.set_nodelay(true).context("configuring worker socket")?;
                    stream
                        .set_read_timeout(self.io_timeout)
                        .context("configuring worker socket")?;
                    self.conns.push(WorkerConn { stream });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() > deadline {
                        bail!(
                            "timed out waiting for workers: {} of {} connected within {:?} \
                             — start them with `mplda worker --connect {}`",
                            self.conns.len(),
                            self.expected,
                            HANDSHAKE_WAIT,
                            self.addr
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e).context("accepting worker connection"),
            }
        }
        self.listener.set_nonblocking(false).context("restoring master listener")?;
        for (i, conn) in self.conns.iter_mut().enumerate() {
            match conn.recv().with_context(|| format!("worker {i} handshake"))? {
                Message::Register => {}
                other => bail!("worker {i}: expected register, got {:?}", other.kind()),
            }
            conn.send(&Message::Init(self.init.clone()))
                .with_context(|| format!("sending init to worker {i}"))?;
            match conn.recv().with_context(|| format!("worker {i} handshake"))? {
                Message::Ready { corpus_fp: fp } if fp == corpus_fp => {}
                Message::Ready { corpus_fp: fp } => bail!(
                    "worker {i} rebuilt a different corpus (fingerprint {fp:#x}, \
                     master has {corpus_fp:#x}) — config drift between processes"
                ),
                other => bail!("worker {i}: expected ready, got {:?}", other.kind()),
            }
        }
        log::info!("distributed: {} workers registered on {}", self.conns.len(), self.addr);
        Ok(())
    }

    /// Start-of-round residency reconciliation: size the tracking
    /// vectors, detect shard reassignments, and fold any pending
    /// invalidation into one epoch bump.
    fn reconcile_epoch(&mut self, ctx: &RoundCtx<'_>) {
        let n = ctx.workers.len();
        if self.resident.len() != n {
            self.resident = vec![None; n];
            self.resident_ck = vec![None; n];
            self.resident_docs = vec![Vec::new(); n];
            self.stale = true;
        }
        let docs_changed =
            (0..n).any(|i| self.resident_docs[i] != ctx.workers[i].docs);
        if self.stale || docs_changed {
            self.epoch += 1;
            self.stale = false;
            for i in 0..n {
                if self.resident_docs[i] != ctx.workers[i].docs {
                    self.resident_docs[i] = ctx.workers[i].docs.clone();
                }
            }
            log::debug!("distributed: epoch -> {} (full resend pending)", self.epoch);
        }
    }
}

/// One scrape conversation: read one JSON frame, answer the `metrics`
/// verb with the Prometheus text rendering, anything else with a typed
/// error frame. Same `serve::wire` framing the serve tier speaks, so
/// [`crate::serve::Client`]-style callers work against the master too.
fn serve_scrape(stream: &mut TcpStream, body: &str) -> Result<()> {
    // The accepted socket may inherit the listener's polling mode.
    stream.set_nonblocking(false).context("configuring scrape socket")?;
    stream
        .set_read_timeout(Some(Duration::from_secs(2)))
        .context("configuring scrape socket")?;
    let Some(req) = read_frame(stream)? else { return Ok(()) };
    let reply = match req.get("type").and_then(Json::as_str) {
        Some("metrics") => Json::Obj(vec![
            ("type".into(), Json::str("metrics")),
            ("body".into(), Json::str(body)),
        ]),
        other => Json::Obj(vec![
            ("type".into(), Json::str("error")),
            (
                "error".into(),
                Json::str(format!("unknown master verb {other:?}; supported: metrics")),
            ),
        ]),
    };
    write_frame(stream, &reply)
}

/// Re-base one worker's piggybacked phase offsets onto the master clock
/// at task-send time and merge them into the cluster trace, with the
/// worker process as pid `1 + connection index`. Offsets ignore the
/// network flight time — good enough for a phase breakdown, and the
/// alternative (clock sync) buys nothing a simulator needs.
fn merge_phases(
    tracer: &Tracer,
    pid: u32,
    position: usize,
    sent_us: u64,
    phases: &[PhaseSample],
) {
    for p in phases {
        tracer.record_unsampled(TraceEvent {
            pid,
            tid: tid_worker(position),
            name: p.phase.name().into(),
            cat: "worker",
            ts_us: sent_us + p.start_us,
            dur_us: p.dur_us,
        });
    }
}

/// Build one position's full-state task from the master's authoritative
/// state.
fn build_task(
    ctx: &RoundCtx<'_>,
    position: usize,
    epoch: u64,
    block: &crate::model::ModelBlock,
    trace: bool,
) -> TaskMsg {
    let w = &ctx.workers[position];
    let z = w.docs.iter().map(|&d| ctx.z[d as usize].clone()).collect();
    let dt = w.docs.iter().map(|&d| ctx.dt.doc(d as usize).iter().collect()).collect();
    TaskMsg {
        position,
        round: ctx.round,
        epoch,
        block: codec::encode_block(block),
        ck: codec::encode_totals(&w.ck),
        rng: w.rng.to_raw(),
        docs: w.docs.clone(),
        z,
        dt,
        trace,
    }
}

/// Splice one full result back into the master's state, exactly where a
/// local round would have left it.
fn apply_result(ctx: &mut RoundCtx<'_>, r: &ResultMsg) -> Result<crate::model::ModelBlock> {
    let w = &mut ctx.workers[r.position];
    if r.z.len() != w.docs.len() || r.dt.len() != w.docs.len() {
        bail!(
            "worker result for position {} covers {} z rows / {} dt rows, shard has {} docs",
            r.position,
            r.z.len(),
            r.dt.len(),
            w.docs.len()
        );
    }
    let ck = codec::decode_totals(&r.ck).context("decoding result C_k")?;
    if ck.num_topics() != ctx.params.num_topics {
        bail!(
            "worker result C_k has {} topics, model has {}",
            ck.num_topics(),
            ctx.params.num_topics
        );
    }
    let block = codec::decode_block(&r.block).context("decoding result block")?;
    w.rng = Pcg64::from_raw(r.rng.0, r.rng.1);
    w.ck = ck;
    w.tokens_sampled += r.tokens;
    for ((&d, z_row), dt_row) in w.docs.iter().zip(&r.z).zip(&r.dt) {
        ctx.z[d as usize] = z_row.clone();
        // Live order ships verbatim: the samplers' bucket-walk and FP
        // summation order depend on it (same contract as bitwise resume).
        *ctx.dt.doc_mut(d as usize) = SparseCounts::from_ordered_entries(dt_row.clone());
    }
    Ok(block)
}

/// Splice one delta result back: patch the leased block in place (the
/// delta codec hard-checks it targets exactly that block), patch the
/// position's `C_k`, and apply the per-doc assignment diffs. Ends in the
/// identical state [`apply_result`] reaches from a full reply.
fn apply_result_delta(
    ctx: &mut RoundCtx<'_>,
    r: &ResultDeltaMsg,
    leased: &mut crate::model::ModelBlock,
) -> Result<()> {
    let w = &mut ctx.workers[r.position];
    if r.z.len() != w.docs.len() || r.dt.len() != w.docs.len() {
        bail!(
            "worker delta result for position {} covers {} z rows / {} dt rows, \
             shard has {} docs",
            r.position,
            r.z.len(),
            r.dt.len(),
            w.docs.len()
        );
    }
    codec::apply_block_delta(leased, &r.block_delta).context("applying result block delta")?;
    codec::apply_totals_delta(&mut w.ck, &r.ck_delta).context("applying result C_k delta")?;
    w.rng = Pcg64::from_raw(r.rng.0, r.rng.1);
    w.tokens_sampled += r.tokens;
    for ((&d, z_diff), dt_row) in w.docs.iter().zip(&r.z).zip(&r.dt) {
        apply_z_row_diff(&mut ctx.z[d as usize], z_diff)
            .with_context(|| format!("applying assignment diff for doc {d}"))?;
        *ctx.dt.doc_mut(d as usize) = SparseCounts::from_ordered_entries(dt_row.clone());
    }
    Ok(())
}

impl Backend for DistributedBackend {
    fn name(&self) -> &'static str {
        "distributed"
    }

    fn listen_addr(&self) -> Option<SocketAddr> {
        Some(self.addr)
    }

    fn attach_obs(&mut self, _tracer: Tracer, registry: Arc<obs::Registry>) {
        self.registry = Some(registry);
    }

    fn reset_workers(&mut self, _workers: usize) -> Result<()> {
        // Checkpoint restore: every master-side structure was rebuilt,
        // so no worker-resident state can be trusted.
        self.stale = true;
        self.resident.clear();
        self.resident_ck.clear();
        self.resident_docs.clear();
        Ok(())
    }

    fn invalidate_worker_cache(&mut self) {
        // Driver-side mutation outside our rounds (degraded rounds run
        // the kernel locally on the master): resident z/dt/C_k bases are
        // stale. One epoch bump → full resends next round.
        self.stale = true;
    }

    fn run_round(&mut self, ctx: &mut RoundCtx<'_>) -> Result<RoundOutcome> {
        if ctx.sampler == SamplerKind::Xla {
            bail!("distributed execution requires a CPU sampler kernel (worker processes \
                   cannot share the device executor)");
        }
        if !self.handshook {
            self.handshake(corpus_fingerprint(ctx.corpus))?;
            self.handshook = true;
            // Leave the listener in polling mode: every worker is
            // registered, so from here on it only answers scrapes.
            self.listener
                .set_nonblocking(true)
                .context("arming the master metrics listener")?;
        }
        if self.conns.is_empty() {
            bail!("every worker process has disconnected; cannot run the round");
        }
        self.poll_scrapes();
        self.reconcile_epoch(ctx);
        let tracer = ctx.tracer.clone();
        let trace = tracer.active();
        let n = ctx.workers.len();
        let (mut leased, fetch_times) = lease_blocks_sync(ctx)?;
        let leased_ids: Vec<u32> = leased.iter().map(|b| b.id).collect();

        // ---- Compute phase, remote -----------------------------------
        // Positions are dealt round-robin over the live connections and
        // exchanged one wave at a time (send a task to every connection,
        // then collect every result), so each socket holds at most one
        // in-flight task — no unbounded buffering, strict request/reply.
        // A socket failure marks the connection dead; its remaining
        // positions simply never produce results.
        let t_compute = Instant::now();
        let nc = self.conns.len();
        let mut per_conn: Vec<Vec<usize>> = vec![Vec::new(); nc];
        for i in 0..n {
            per_conn[i % nc].push(i);
        }
        let waves = per_conn.iter().map(Vec::len).max().unwrap_or(0);
        let mut conn_ok = vec![true; nc];
        let mut results: Vec<Option<RoundResult>> = (0..n).map(|_| None).collect();
        // Master-clock µs at each task's send, the re-base anchor for
        // that task's piggybacked phase timings (zero when untraced).
        let mut send_ts = vec![0u64; n];
        for wave in 0..waves {
            for (c, positions) in per_conn.iter().enumerate() {
                let Some(&i) = positions.get(wave) else { continue };
                if !conn_ok[c] {
                    continue;
                }
                let machine = ctx.workers[i].machine;
                if trace {
                    send_ts[i] = tracer.now_us();
                }
                let sent = if !self.delta {
                    let task = Message::Task(build_task(ctx, i, self.epoch, &leased[i], trace));
                    self.conns[c]
                        .send_json(&task, self.max_frame)
                        .map(|b| (b, TransferKind::TaskFull))
                } else if self.resident[i] == Some(self.epoch) && self.resident_ck[i].is_some() {
                    let w = &ctx.workers[i];
                    let task = BinMsg::TaskDelta(TaskDeltaMsg {
                        position: i,
                        round: ctx.round,
                        epoch: self.epoch,
                        rng: w.rng.to_raw(),
                        block: codec::encode_block(&leased[i]),
                        ck_delta: codec::encode_totals_delta(
                            self.resident_ck[i].as_ref().unwrap(),
                            &w.ck,
                        ),
                        trace,
                    });
                    self.conns[c]
                        .send_bin(&task, self.max_frame)
                        .map(|b| (b, TransferKind::TaskDelta))
                } else {
                    let task =
                        BinMsg::TaskFull(build_task(ctx, i, self.epoch, &leased[i], trace));
                    self.conns[c]
                        .send_bin(&task, self.max_frame)
                        .map(|b| (b, TransferKind::TaskFull))
                };
                match sent {
                    Ok((bytes, kind)) => ctx.kv.record_transport(machine, bytes, kind),
                    Err(e) => {
                        log::warn!("distributed: worker conn {c} failed on send: {e:#}");
                        conn_ok[c] = false;
                    }
                }
            }
            let _wait_span = tracer.span(0, TID_DRIVER, "result_wait", "coord");
            let t_wave = Instant::now();
            for (c, positions) in per_conn.iter().enumerate() {
                let Some(&i) = positions.get(wave) else { continue };
                if !conn_ok[c] {
                    continue;
                }
                let machine = ctx.workers[i].machine;
                match self.conns[c].recv_any(self.max_frame) {
                    Ok((AnyMsg::Json(Message::Result(r)), bytes)) if r.position == i => {
                        ctx.kv.record_transport(machine, bytes, TransferKind::ResultFull);
                        self.round_wait.record(t_wave.elapsed().as_micros() as u64);
                        if trace {
                            merge_phases(&tracer, c as u32 + 1, i, send_ts[i], &r.phases);
                        }
                        results[i] = Some(RoundResult::Full(r));
                    }
                    Ok((AnyMsg::Bin(BinMsg::ResultDelta(r)), bytes)) if r.position == i => {
                        ctx.kv.record_transport(machine, bytes, TransferKind::ResultDelta);
                        self.round_wait.record(t_wave.elapsed().as_micros() as u64);
                        if trace {
                            merge_phases(&tracer, c as u32 + 1, i, send_ts[i], &r.phases);
                        }
                        results[i] = Some(RoundResult::Delta(r));
                    }
                    Ok((AnyMsg::Json(Message::Result(r)), _)) => {
                        bail!("worker answered position {} for a task at position {i}", r.position)
                    }
                    Ok((AnyMsg::Bin(BinMsg::ResultDelta(r)), _)) => {
                        bail!("worker answered position {} for a task at position {i}", r.position)
                    }
                    Ok((AnyMsg::Json(other), _)) => {
                        bail!("expected a result frame, got {:?}", other.kind())
                    }
                    Ok((AnyMsg::Bin(_), _)) => {
                        bail!("expected a result frame, got a binary task")
                    }
                    Err(e) => {
                        log::warn!("distributed: worker conn {c} failed on receive: {e:#}");
                        conn_ok[c] = false;
                    }
                }
            }
        }

        // ---- Apply results, position order ---------------------------
        let mut tokens = 0u64;
        let mut host_secs = vec![0.0f64; n];
        for i in 0..n {
            let Some(r) = results[i].as_ref() else { continue };
            match r {
                RoundResult::Full(r) => {
                    require_epoch(i, r.epoch, Some(self.epoch))?;
                    let block = apply_result(ctx, r)?;
                    if block.id != leased_ids[i] {
                        bail!(
                            "worker returned block {} for leased block {}",
                            block.id,
                            leased_ids[i]
                        );
                    }
                    host_secs[i] = r.host_secs;
                    tokens += r.tokens;
                    leased[i] = block;
                }
                RoundResult::Delta(r) => {
                    require_epoch(i, r.epoch, Some(self.epoch))?;
                    apply_result_delta(ctx, r, &mut leased[i])?;
                    host_secs[i] = r.host_secs;
                    tokens += r.tokens;
                }
            }
            if self.delta {
                // The worker's resident state now equals the master's
                // post-apply state; snapshot the C_k base *now* (the
                // driver may overwrite w.ck with a totals sync before
                // the next round — the delta from this base covers it).
                self.resident[i] = Some(self.epoch);
                self.resident_ck[i] = Some(ctx.workers[i].ck.clone());
            }
        }
        ctx.pstats.sample_secs += t_compute.elapsed().as_secs_f64();

        // ---- Commit phase, worker order (skipping corpses) -----------
        // Byte-identical to `commit_blocks_sync` for the healthy
        // positions; a corpse's lease stays out (uncommitted — the state
        // a crash leaves) and only its memory charge is returned.
        let t_flush = Instant::now();
        let _commit_span = tracer.span(0, TID_DRIVER, "commit", "coord");
        let mut dead: Vec<(usize, u32)> = Vec::new();
        let mut merge_bytes_per_worker = 0u64;
        for (i, (w, blk)) in ctx.workers.iter_mut().zip(leased).enumerate() {
            ctx.mem.release(w.machine, MemCategory::Model, blk.bytes());
            if results[i].is_none() {
                dead.push((i, leased_ids[i]));
                // Whether the worker ran the task is unknowable; drop
                // the residency claim so recovery never deltas against
                // an uncertain base.
                if let Some(r) = self.resident.get_mut(i) {
                    *r = None;
                }
                continue;
            }
            let alias = blk.alias_bytes();
            if alias > 0 {
                ctx.mem.release(w.machine, MemCategory::AliasCache, alias);
            }
            ctx.kv.commit_block(blk, w.machine)?;
            let before = ctx.kv.total_bytes();
            let delta = w.extract_totals_delta();
            ctx.kv.merge_totals_delta(&delta, w.machine);
            merge_bytes_per_worker = ctx.kv.total_bytes() - before;
        }
        let commit_flows: Vec<Flow> = ctx
            .kv
            .pending_transfers()
            .iter()
            .filter(|t| t.what == TransferKind::BlockCommit)
            .map(|t| Flow { src: t.src, dst: t.dst, bytes: t.bytes })
            .collect();
        let _ = ctx.kv.drain_flows();
        let t_commit = ctx.net.phase_time(&commit_flows)
            + ctx.net.reduce_time(merge_bytes_per_worker, ctx.workers.len());
        ctx.pstats.flush_stall_secs += t_flush.elapsed().as_secs_f64();
        ctx.pstats.rounds += 1;

        // Forget broken connections; later rounds re-deal positions over
        // the survivors, which invalidates residency wholesale.
        let mut keep = conn_ok.iter();
        self.conns.retain(|_| *keep.next().unwrap());
        if self.conns.len() != nc {
            self.stale = true;
        }
        self.export_metrics();

        Ok(RoundOutcome { tokens, host_secs, fetch_times, t_commit, dead })
    }
}

impl Drop for DistributedBackend {
    fn drop(&mut self) {
        // Best-effort orderly shutdown so worker processes exit instead
        // of blocking on a read forever; failures are moot (the peer may
        // already be gone).
        for conn in &mut self.conns {
            let _ = conn.stream.set_read_timeout(Some(Duration::from_secs(2)));
            if conn.send(&Message::Shutdown).is_ok() {
                let _ = conn.recv(); // Bye, or whatever is left
            }
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        }
    }
}
