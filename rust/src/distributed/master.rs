//! The master side: a [`Backend`] whose compute phase happens in other
//! processes.
//!
//! ## Determinism argument (summarized in DESIGN.md §Distributed)
//!
//! The backend contract fixes everything except *where* the sampling
//! kernel runs. This backend keeps the lease phase, the commit phase and
//! the `C_k` merge order byte-identical to [`SimulatedBackend`]'s
//! (`lease_blocks_sync` + the worker-ordered commit loop below); the
//! sampling phase ships each position's full working set — leased block,
//! `C_k` snapshot, RNG stream position, assignments and live-order
//! doc–topic entries — to a worker process, which runs the *same*
//! `WorkerState::run_round` lifecycle on the *same* bytes and ships every
//! mutated structure back. Nothing about the computation depends on which
//! process hosts it, so the model trajectory is bitwise equal to the
//! simulated one from the same seed; only wall-clock measurements (which
//! never touch model state) differ.
//!
//! ## Fault path
//!
//! A worker process that dies mid-round takes its socket with it; the
//! send or receive for its positions fails and those positions come back
//! in [`RoundOutcome::dead`]. Their leases are already out (taken in the
//! lease phase) and stay uncommitted — exactly the state a scripted
//! `kill@` fault leaves — so the driver's PR-6 machinery (grace rounds,
//! lease revocation from the recovery copy, rotation reassignment, shard
//! adoption) handles the rest without knowing sockets exist.
//!
//! [`SimulatedBackend`]: crate::engine::backend::SimulatedBackend

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cluster::{Flow, MemCategory};
use crate::config::{Config, SamplerKind};
use crate::engine::backend::{lease_blocks_sync, Backend, RoundCtx, RoundOutcome};
use crate::kvstore::traffic::TransferKind;
use crate::model::checkpoint::corpus_fingerprint;
use crate::model::{wire as codec, SparseCounts};
use crate::serve::wire::{read_frame, write_frame};
use crate::util::rng::Pcg64;

use super::protocol::{InitMsg, Message, ResultMsg, TaskMsg};

/// How long the first round waits for the full worker roster to connect
/// and complete the handshake before giving up.
const HANDSHAKE_WAIT: Duration = Duration::from_secs(120);

/// One registered worker process.
struct WorkerConn {
    stream: TcpStream,
}

impl WorkerConn {
    fn send(&mut self, msg: &Message) -> Result<()> {
        write_frame(&mut self.stream, &msg.to_json())
    }

    fn recv(&mut self) -> Result<Message> {
        match read_frame(&mut self.stream)? {
            Some(j) => Message::from_json(&j),
            None => bail!("worker closed its connection"),
        }
    }
}

/// The `coord.execution = "distributed"` backend: master-side transport
/// plus the lease/commit halves of the round. Binds its listener eagerly
/// at construction (so `Driver::listen_addr` is known before training
/// starts) and completes the worker handshake lazily on the first round
/// (the corpus fingerprint it must verify lives on the driver).
pub struct DistributedBackend {
    listener: TcpListener,
    addr: SocketAddr,
    expected: usize,
    io_timeout: Option<Duration>,
    init: InitMsg,
    conns: Vec<WorkerConn>,
    handshook: bool,
}

impl DistributedBackend {
    /// Bind the listen address from `cfg.dist` and capture the handshake
    /// payload. No worker needs to be running yet.
    pub fn new(cfg: &Config) -> Result<DistributedBackend> {
        if cfg.dist.workers == 0 {
            bail!("dist.workers must be >= 1 (finalize() resolves 0 to coord.workers)");
        }
        let listener = TcpListener::bind(&cfg.dist.listen)
            .with_context(|| format!("binding master listener on {:?}", cfg.dist.listen))?;
        let addr = listener.local_addr().context("reading master listen address")?;
        let io_timeout = if cfg.dist.io_timeout_secs > 0.0 {
            Some(Duration::from_secs_f64(cfg.dist.io_timeout_secs))
        } else {
            None
        };
        let init = InitMsg {
            corpus: cfg.corpus.clone(),
            topics: cfg.train.topics,
            alpha: cfg.train.alpha,
            beta: cfg.train.beta,
            sampler: cfg.train.sampler,
            alias_budget_bytes: (cfg.train.alias_budget_mib * (1u64 << 20) as f64).round() as u64,
            corpus_fp: 0, // filled at handshake, when the corpus exists
        };
        Ok(DistributedBackend {
            listener,
            addr,
            expected: cfg.dist.workers,
            io_timeout,
            init,
            conns: Vec::new(),
            handshook: false,
        })
    }

    /// Accept `expected` connections and run the register→init→ready
    /// handshake on each, verifying every worker rebuilt the identical
    /// corpus.
    fn handshake(&mut self, corpus_fp: u64) -> Result<()> {
        self.init.corpus_fp = corpus_fp;
        self.listener
            .set_nonblocking(true)
            .context("switching master listener to polling")?;
        let deadline = Instant::now() + HANDSHAKE_WAIT;
        while self.conns.len() < self.expected {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    stream.set_nonblocking(false).context("configuring worker socket")?;
                    stream.set_nodelay(true).context("configuring worker socket")?;
                    stream
                        .set_read_timeout(self.io_timeout)
                        .context("configuring worker socket")?;
                    self.conns.push(WorkerConn { stream });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() > deadline {
                        bail!(
                            "timed out waiting for workers: {} of {} connected within {:?} \
                             — start them with `mplda worker --connect {}`",
                            self.conns.len(),
                            self.expected,
                            HANDSHAKE_WAIT,
                            self.addr
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e).context("accepting worker connection"),
            }
        }
        self.listener.set_nonblocking(false).context("restoring master listener")?;
        for (i, conn) in self.conns.iter_mut().enumerate() {
            match conn.recv().with_context(|| format!("worker {i} handshake"))? {
                Message::Register => {}
                other => bail!("worker {i}: expected register, got {:?}", other.kind()),
            }
            conn.send(&Message::Init(self.init.clone()))
                .with_context(|| format!("sending init to worker {i}"))?;
            match conn.recv().with_context(|| format!("worker {i} handshake"))? {
                Message::Ready { corpus_fp: fp } if fp == corpus_fp => {}
                Message::Ready { corpus_fp: fp } => bail!(
                    "worker {i} rebuilt a different corpus (fingerprint {fp:#x}, \
                     master has {corpus_fp:#x}) — config drift between processes"
                ),
                other => bail!("worker {i}: expected ready, got {:?}", other.kind()),
            }
        }
        log::info!("distributed: {} workers registered on {}", self.conns.len(), self.addr);
        Ok(())
    }
}

/// Build one position's task message from the master's authoritative
/// state.
fn build_task(ctx: &RoundCtx<'_>, position: usize, block: &crate::model::ModelBlock) -> TaskMsg {
    let w = &ctx.workers[position];
    let z = w.docs.iter().map(|&d| ctx.z[d as usize].clone()).collect();
    let dt = w.docs.iter().map(|&d| ctx.dt.doc(d as usize).iter().collect()).collect();
    TaskMsg {
        position,
        round: ctx.round,
        block: codec::encode_block(block),
        ck: codec::encode_totals(&w.ck),
        rng: w.rng.to_raw(),
        docs: w.docs.clone(),
        z,
        dt,
    }
}

/// Splice one result back into the master's state, exactly where a local
/// round would have left it.
fn apply_result(ctx: &mut RoundCtx<'_>, r: &ResultMsg) -> Result<crate::model::ModelBlock> {
    let w = &mut ctx.workers[r.position];
    if r.z.len() != w.docs.len() || r.dt.len() != w.docs.len() {
        bail!(
            "worker result for position {} covers {} z rows / {} dt rows, shard has {} docs",
            r.position,
            r.z.len(),
            r.dt.len(),
            w.docs.len()
        );
    }
    let ck = codec::decode_totals(&r.ck).context("decoding result C_k")?;
    if ck.num_topics() != ctx.params.num_topics {
        bail!(
            "worker result C_k has {} topics, model has {}",
            ck.num_topics(),
            ctx.params.num_topics
        );
    }
    let block = codec::decode_block(&r.block).context("decoding result block")?;
    w.rng = Pcg64::from_raw(r.rng.0, r.rng.1);
    w.ck = ck;
    w.tokens_sampled += r.tokens;
    for ((&d, z_row), dt_row) in w.docs.iter().zip(&r.z).zip(&r.dt) {
        ctx.z[d as usize] = z_row.clone();
        // Live order ships verbatim: the samplers' bucket-walk and FP
        // summation order depend on it (same contract as bitwise resume).
        *ctx.dt.doc_mut(d as usize) = SparseCounts::from_ordered_entries(dt_row.clone());
    }
    Ok(block)
}

impl Backend for DistributedBackend {
    fn name(&self) -> &'static str {
        "distributed"
    }

    fn listen_addr(&self) -> Option<SocketAddr> {
        Some(self.addr)
    }

    fn run_round(&mut self, ctx: &mut RoundCtx<'_>) -> Result<RoundOutcome> {
        if ctx.sampler == SamplerKind::Xla {
            bail!("distributed execution requires a CPU sampler kernel (worker processes \
                   cannot share the device executor)");
        }
        if !self.handshook {
            self.handshake(corpus_fingerprint(ctx.corpus))?;
            self.handshook = true;
        }
        if self.conns.is_empty() {
            bail!("every worker process has disconnected; cannot run the round");
        }
        let n = ctx.workers.len();
        let (mut leased, fetch_times) = lease_blocks_sync(ctx)?;
        let leased_ids: Vec<u32> = leased.iter().map(|b| b.id).collect();

        // ---- Compute phase, remote -----------------------------------
        // Positions are dealt round-robin over the live connections and
        // exchanged one wave at a time (send a task to every connection,
        // then collect every result), so each socket holds at most one
        // in-flight task — no unbounded buffering, strict request/reply.
        // A socket failure marks the connection dead; its remaining
        // positions simply never produce results.
        let t_compute = Instant::now();
        let nc = self.conns.len();
        let mut per_conn: Vec<Vec<usize>> = vec![Vec::new(); nc];
        for i in 0..n {
            per_conn[i % nc].push(i);
        }
        let waves = per_conn.iter().map(Vec::len).max().unwrap_or(0);
        let mut conn_ok = vec![true; nc];
        let mut results: Vec<Option<ResultMsg>> = (0..n).map(|_| None).collect();
        for wave in 0..waves {
            for (c, positions) in per_conn.iter().enumerate() {
                let Some(&i) = positions.get(wave) else { continue };
                if !conn_ok[c] {
                    continue;
                }
                let task = Message::Task(build_task(ctx, i, &leased[i]));
                if let Err(e) = self.conns[c].send(&task) {
                    log::warn!("distributed: worker conn {c} failed on send: {e:#}");
                    conn_ok[c] = false;
                }
            }
            for (c, positions) in per_conn.iter().enumerate() {
                let Some(&i) = positions.get(wave) else { continue };
                if !conn_ok[c] {
                    continue;
                }
                match self.conns[c].recv() {
                    Ok(Message::Result(r)) if r.position == i => results[i] = Some(r),
                    Ok(Message::Result(r)) => {
                        bail!("worker answered position {} for a task at position {i}", r.position)
                    }
                    Ok(other) => {
                        bail!("expected a result frame, got {:?}", other.kind())
                    }
                    Err(e) => {
                        log::warn!("distributed: worker conn {c} failed on receive: {e:#}");
                        conn_ok[c] = false;
                    }
                }
            }
        }

        // ---- Apply results, position order ---------------------------
        let mut tokens = 0u64;
        let mut host_secs = vec![0.0f64; n];
        for i in 0..n {
            if let Some(r) = results[i].take() {
                let block = apply_result(ctx, &r)?;
                if block.id != leased_ids[i] {
                    bail!("worker returned block {} for leased block {}", block.id, leased_ids[i]);
                }
                leased[i] = block;
                host_secs[i] = r.host_secs;
                tokens += r.tokens;
                results[i] = Some(r);
            }
        }
        ctx.pstats.sample_secs += t_compute.elapsed().as_secs_f64();

        // ---- Commit phase, worker order (skipping corpses) -----------
        // Byte-identical to `commit_blocks_sync` for the healthy
        // positions; a corpse's lease stays out (uncommitted — the state
        // a crash leaves) and only its memory charge is returned.
        let t_flush = Instant::now();
        let mut dead: Vec<(usize, u32)> = Vec::new();
        let mut merge_bytes_per_worker = 0u64;
        for (i, (w, blk)) in ctx.workers.iter_mut().zip(leased).enumerate() {
            ctx.mem.release(w.machine, MemCategory::Model, blk.bytes());
            if results[i].is_none() {
                dead.push((i, leased_ids[i]));
                continue;
            }
            let alias = blk.alias_bytes();
            if alias > 0 {
                ctx.mem.release(w.machine, MemCategory::AliasCache, alias);
            }
            ctx.kv.commit_block(blk, w.machine)?;
            let before = ctx.kv.total_bytes();
            let delta = w.extract_totals_delta();
            ctx.kv.merge_totals_delta(&delta, w.machine);
            merge_bytes_per_worker = ctx.kv.total_bytes() - before;
        }
        let commit_flows: Vec<Flow> = ctx
            .kv
            .pending_transfers()
            .iter()
            .filter(|t| t.what == TransferKind::BlockCommit)
            .map(|t| Flow { src: t.src, dst: t.dst, bytes: t.bytes })
            .collect();
        let _ = ctx.kv.drain_flows();
        let t_commit = ctx.net.phase_time(&commit_flows)
            + ctx.net.reduce_time(merge_bytes_per_worker, ctx.workers.len());
        ctx.pstats.flush_stall_secs += t_flush.elapsed().as_secs_f64();
        ctx.pstats.rounds += 1;

        // Forget broken connections; later rounds re-deal positions over
        // the survivors.
        let mut keep = conn_ok.iter();
        self.conns.retain(|_| *keep.next().unwrap());

        Ok(RoundOutcome { tokens, host_secs, fetch_times, t_commit, dead })
    }
}

impl Drop for DistributedBackend {
    fn drop(&mut self) {
        // Best-effort orderly shutdown so worker processes exit instead
        // of blocking on a read forever; failures are moot (the peer may
        // already be gone).
        for conn in &mut self.conns {
            let _ = conn.stream.set_read_timeout(Some(Duration::from_secs(2)));
            if conn.send(&Message::Shutdown).is_ok() {
                let _ = conn.recv(); // Bye, or whatever is left
            }
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        }
    }
}
