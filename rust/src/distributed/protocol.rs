//! The master↔worker message vocabulary, as typed structs with lossless
//! JSON codecs.
//!
//! Every message travels as one `serve::wire` frame (length-prefixed
//! JSON, shared cap and typed framing errors). The conversation is a
//! strict state machine per connection:
//!
//! ```text
//!  worker                         master
//!    │ ── register ──────────────► │   (once, at connect)
//!    │ ◄────────────────── init ── │   corpus recipe + hyperparameters
//!    │ ── ready{corpus_fp} ──────► │   fingerprints must agree
//!    │                             │
//!    │ ◄────────────────── task ── │ ┐ one per (position, round):
//!    │ ── result ────────────────► │ ┘ full task state both ways
//!    │          …                  │
//!    │ ◄────────────── shutdown ── │
//!    │ ── bye ───────────────────► │   then both sides close
//! ```
//!
//! **Numbers on the wire.** `serve::json` renders `f64` and integers are
//! exact only up to 2^53, so anything wider rides as a decimal *string*:
//! the two `u128` halves of a PCG64 state, and the `u64` corpus
//! fingerprint. Block and totals payloads reuse the binary checkpoint
//! codec (`model::wire`, LEB128 + zigzag) hex-encoded into a JSON string
//! — one codec for disk and socket, one set of validation errors.
//!
//! **Why ship full task state every round?** The master stays the single
//! authority over `z`, `C_d^k`, worker RNG streams and `C_k` snapshots;
//! workers are pure compute. A round's task therefore carries everything
//! the sampler kernel reads, and its result carries everything the kernel
//! wrote — which is what makes the distributed trajectory *bitwise* equal
//! to the simulated one (the worker runs the identical
//! `WorkerState::run_round` on identical inputs) and makes worker death
//! recoverable by construction: a corpse holds no state the master does
//! not already have, except the one uncommitted round the lease-timeout
//! protocol is designed to sacrifice.

use anyhow::{bail, Context, Result};

use crate::config::{CorpusConfig, SamplerKind};
use crate::serve::json::Json;

/// One protocol message, either direction. `Json`-codable losslessly;
/// `tests/prop_protocol.rs` round-trips every variant through the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker → master: first frame after connect.
    Register,
    /// Master → worker: everything needed to rebuild the shared world.
    Init(InitMsg),
    /// Worker → master: corpus rebuilt; `corpus_fp` proves it is the
    /// same corpus bit for bit.
    Ready {
        /// `model::checkpoint::corpus_fingerprint` of the rebuilt corpus.
        corpus_fp: u64,
    },
    /// Master → worker: one `(position, round)` sampling task.
    Task(TaskMsg),
    /// Worker → master: the completed task's full output state.
    Result(ResultMsg),
    /// Master → worker: training is over, close after `Bye`.
    Shutdown,
    /// Worker → master: acknowledges `Shutdown`; the socket closes next.
    Bye,
}

/// The master's handshake payload: a *recipe* for the corpus (workers
/// rebuild it locally — deterministic from its config — instead of
/// streaming gigabytes of tokens) plus every hyperparameter the sampler
/// kernel reads.
#[derive(Debug, Clone, PartialEq)]
pub struct InitMsg {
    /// Corpus recipe; `corpus::build` is deterministic in it.
    pub corpus: CorpusConfig,
    /// Topic count `K`.
    pub topics: usize,
    /// Dirichlet hyperparameter α.
    pub alpha: f64,
    /// Dirichlet hyperparameter β.
    pub beta: f64,
    /// Sampler kernel every task runs.
    pub sampler: SamplerKind,
    /// `train.alias_budget_mib` in bytes (mh-alias proposal tables).
    pub alias_budget_bytes: u64,
    /// Master-side corpus fingerprint the worker must reproduce.
    pub corpus_fp: u64,
}

/// One round's task for one rotation position: the leased block, the
/// position's `C_k` snapshot and RNG stream, and the doc-shard state
/// (assignments + live-order doc–topic entries, one row per doc of
/// `docs`, in `docs` order).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskMsg {
    /// Rotation position this task computes.
    pub position: usize,
    /// Round index within the iteration (diagnostics only).
    pub round: usize,
    /// `model::wire::encode_block` bytes of the leased block.
    pub block: Vec<u8>,
    /// `model::wire::encode_totals` bytes of the position's `C_k`.
    pub ck: Vec<u8>,
    /// Raw PCG64 `(state, inc)` of the position's RNG stream.
    pub rng: (u128, u128),
    /// The position's document shard (global doc ids, sorted).
    pub docs: Vec<u32>,
    /// Topic assignments, one row per doc of `docs`, in order.
    pub z: Vec<Vec<u32>>,
    /// Doc–topic counts in **live storage order** (descending by count —
    /// the samplers' walk order, so it must survive the trip verbatim),
    /// one row per doc of `docs`.
    pub dt: Vec<Vec<(u32, u32)>>,
}

/// A completed task: every piece of state the kernel mutated, shipped
/// back so the master can splice it in as if it had sampled locally.
#[derive(Debug, Clone, PartialEq)]
pub struct ResultMsg {
    /// Rotation position this result answers.
    pub position: usize,
    /// Tokens sampled.
    pub tokens: u64,
    /// Thread CPU seconds the kernel took (drives the simulated clocks;
    /// never model state).
    pub host_secs: f64,
    /// Updated block bytes (`model::wire::encode_block`).
    pub block: Vec<u8>,
    /// Updated `C_k` snapshot bytes.
    pub ck: Vec<u8>,
    /// RNG stream position after the round.
    pub rng: (u128, u128),
    /// Updated assignments, rows matching the task's `docs` order.
    pub z: Vec<Vec<u32>>,
    /// Updated doc–topic counts, live order, rows matching `docs`.
    pub dt: Vec<Vec<(u32, u32)>>,
}

// ---------------------------------------------------------------------
// Encoding helpers
// ---------------------------------------------------------------------

/// Hex-encode binary payload bytes for a JSON string field.
pub fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        s.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
    }
    s
}

/// Decode [`hex_encode`] output; typed errors on odd length or non-hex.
pub fn hex_decode(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        bail!("hex payload has odd length {}", s.len());
    }
    let digits = s.as_bytes();
    let mut out = Vec::with_capacity(s.len() / 2);
    for pair in digits.chunks_exact(2) {
        let hi = (pair[0] as char).to_digit(16).context("non-hex byte in payload")?;
        let lo = (pair[1] as char).to_digit(16).context("non-hex byte in payload")?;
        out.push(((hi << 4) | lo) as u8);
    }
    Ok(out)
}

/// `u64` as a decimal JSON string (`Json::Num` is exact only to 2^53).
fn u64_str(v: u64) -> Json {
    Json::str(v.to_string())
}

fn get_u64_str(j: &Json, key: &str) -> Result<u64> {
    j.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("missing string field {key:?}"))?
        .parse::<u64>()
        .with_context(|| format!("field {key:?} is not a u64"))
}

fn get_u128_pair(j: &Json, key: &str) -> Result<(u128, u128)> {
    let arr = j
        .get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing array field {key:?}"))?;
    if arr.len() != 2 {
        bail!("field {key:?} must be a [state, inc] pair, got {} entries", arr.len());
    }
    let part = |i: usize| -> Result<u128> {
        arr[i]
            .as_str()
            .with_context(|| format!("field {key:?}[{i}] is not a string"))?
            .parse::<u128>()
            .with_context(|| format!("field {key:?}[{i}] is not a u128"))
    };
    Ok((part(0)?, part(1)?))
}

fn rng_json((state, inc): (u128, u128)) -> Json {
    Json::Arr(vec![Json::str(state.to_string()), Json::str(inc.to_string())])
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_u64)
        .with_context(|| format!("missing integer field {key:?}"))
        .map(|v| v as usize)
}

fn get_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing number field {key:?}"))
}

fn get_str<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("missing string field {key:?}"))
}

fn get_hex(j: &Json, key: &str) -> Result<Vec<u8>> {
    hex_decode(get_str(j, key)?).with_context(|| format!("decoding hex field {key:?}"))
}

fn z_json(z: &[Vec<u32>]) -> Json {
    Json::Arr(
        z.iter()
            .map(|row| Json::Arr(row.iter().map(|&t| Json::num(t as f64)).collect()))
            .collect(),
    )
}

fn get_z(j: &Json, key: &str) -> Result<Vec<Vec<u32>>> {
    let rows = j
        .get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing array field {key:?}"))?;
    rows.iter()
        .map(|row| {
            row.as_arr()
                .context("assignment row is not an array")?
                .iter()
                .map(|t| {
                    let v = t.as_u64().context("assignment is not a non-negative integer")?;
                    u32::try_from(v).context("assignment exceeds u32")
                })
                .collect()
        })
        .collect()
}

/// Doc–topic rows as flat `[t0,c0,t1,c1,…]` arrays — half the JSON nodes
/// of nested pairs, and the flat order *is* the live storage order.
fn dt_json(dt: &[Vec<(u32, u32)>]) -> Json {
    Json::Arr(
        dt.iter()
            .map(|row| {
                let mut flat = Vec::with_capacity(row.len() * 2);
                for &(t, c) in row {
                    flat.push(Json::num(t as f64));
                    flat.push(Json::num(c as f64));
                }
                Json::Arr(flat)
            })
            .collect(),
    )
}

fn get_dt(j: &Json, key: &str) -> Result<Vec<Vec<(u32, u32)>>> {
    let rows = j
        .get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing array field {key:?}"))?;
    rows.iter()
        .map(|row| {
            let flat = row.as_arr().context("doc-topic row is not an array")?;
            if flat.len() % 2 != 0 {
                bail!("doc-topic row has odd length {}", flat.len());
            }
            flat.chunks_exact(2)
                .map(|pair| {
                    let t = pair[0].as_u64().context("doc-topic topic is not an integer")?;
                    let c = pair[1].as_u64().context("doc-topic count is not an integer")?;
                    Ok((
                        u32::try_from(t).context("topic exceeds u32")?,
                        u32::try_from(c).context("count exceeds u32")?,
                    ))
                })
                .collect()
        })
        .collect()
}

impl Message {
    /// The `"type"` tag this message carries on the wire.
    pub fn kind(&self) -> &'static str {
        match self {
            Message::Register => "register",
            Message::Init(_) => "init",
            Message::Ready { .. } => "ready",
            Message::Task(_) => "task",
            Message::Result(_) => "result",
            Message::Shutdown => "shutdown",
            Message::Bye => "bye",
        }
    }

    /// Encode for one wire frame.
    pub fn to_json(&self) -> Json {
        let tag = ("type".to_string(), Json::str(self.kind()));
        match self {
            Message::Register | Message::Shutdown | Message::Bye => Json::Obj(vec![tag]),
            Message::Ready { corpus_fp } => {
                Json::Obj(vec![tag, ("corpus_fp".into(), u64_str(*corpus_fp))])
            }
            Message::Init(m) => Json::Obj(vec![
                tag,
                ("corpus_preset".into(), Json::str(&m.corpus.preset)),
                ("corpus_vocab".into(), Json::num(m.corpus.vocab as f64)),
                ("corpus_docs".into(), Json::num(m.corpus.docs as f64)),
                ("corpus_avg_doc_len".into(), Json::num(m.corpus.avg_doc_len as f64)),
                ("corpus_zipf_s".into(), Json::num(m.corpus.zipf_s)),
                ("corpus_gen_topics".into(), Json::num(m.corpus.gen_topics as f64)),
                ("corpus_gen_alpha".into(), Json::num(m.corpus.gen_alpha)),
                ("corpus_gen_beta".into(), Json::num(m.corpus.gen_beta)),
                ("corpus_bigram".into(), Json::Bool(m.corpus.bigram)),
                ("corpus_path".into(), Json::str(&m.corpus.path)),
                ("corpus_seed".into(), u64_str(m.corpus.seed)),
                ("topics".into(), Json::num(m.topics as f64)),
                ("alpha".into(), Json::num(m.alpha)),
                ("beta".into(), Json::num(m.beta)),
                ("sampler".into(), Json::str(m.sampler.name())),
                ("alias_budget_bytes".into(), u64_str(m.alias_budget_bytes)),
                ("corpus_fp".into(), u64_str(m.corpus_fp)),
            ]),
            Message::Task(m) => Json::Obj(vec![
                tag,
                ("position".into(), Json::num(m.position as f64)),
                ("round".into(), Json::num(m.round as f64)),
                ("block".into(), Json::str(hex_encode(&m.block))),
                ("ck".into(), Json::str(hex_encode(&m.ck))),
                ("rng".into(), rng_json(m.rng)),
                (
                    "docs".into(),
                    Json::Arr(m.docs.iter().map(|&d| Json::num(d as f64)).collect()),
                ),
                ("z".into(), z_json(&m.z)),
                ("dt".into(), dt_json(&m.dt)),
            ]),
            Message::Result(m) => Json::Obj(vec![
                tag,
                ("position".into(), Json::num(m.position as f64)),
                ("tokens".into(), u64_str(m.tokens)),
                ("host_secs".into(), Json::num(m.host_secs)),
                ("block".into(), Json::str(hex_encode(&m.block))),
                ("ck".into(), Json::str(hex_encode(&m.ck))),
                ("rng".into(), rng_json(m.rng)),
                ("z".into(), z_json(&m.z)),
                ("dt".into(), dt_json(&m.dt)),
            ]),
        }
    }

    /// Decode one wire frame; typed errors on unknown tags or malformed
    /// fields — never a panic (the peer controls these bytes).
    pub fn from_json(j: &Json) -> Result<Message> {
        let kind = get_str(j, "type")?;
        Ok(match kind {
            "register" => Message::Register,
            "shutdown" => Message::Shutdown,
            "bye" => Message::Bye,
            "ready" => Message::Ready { corpus_fp: get_u64_str(j, "corpus_fp")? },
            "init" => {
                let corpus = CorpusConfig {
                    preset: get_str(j, "corpus_preset")?.to_string(),
                    vocab: get_usize(j, "corpus_vocab")?,
                    docs: get_usize(j, "corpus_docs")?,
                    avg_doc_len: get_usize(j, "corpus_avg_doc_len")?,
                    zipf_s: get_f64(j, "corpus_zipf_s")?,
                    gen_topics: get_usize(j, "corpus_gen_topics")?,
                    gen_alpha: get_f64(j, "corpus_gen_alpha")?,
                    gen_beta: get_f64(j, "corpus_gen_beta")?,
                    bigram: matches!(j.get("corpus_bigram"), Some(Json::Bool(true))),
                    path: get_str(j, "corpus_path")?.to_string(),
                    seed: get_u64_str(j, "corpus_seed")?,
                };
                Message::Init(InitMsg {
                    corpus,
                    topics: get_usize(j, "topics")?,
                    alpha: get_f64(j, "alpha")?,
                    beta: get_f64(j, "beta")?,
                    sampler: SamplerKind::parse(get_str(j, "sampler")?)?,
                    alias_budget_bytes: get_u64_str(j, "alias_budget_bytes")?,
                    corpus_fp: get_u64_str(j, "corpus_fp")?,
                })
            }
            "task" => {
                let docs = j
                    .get("docs")
                    .and_then(Json::as_arr)
                    .context("missing array field \"docs\"")?
                    .iter()
                    .map(|d| {
                        let v = d.as_u64().context("doc id is not a non-negative integer")?;
                        u32::try_from(v).context("doc id exceeds u32")
                    })
                    .collect::<Result<Vec<u32>>>()?;
                Message::Task(TaskMsg {
                    position: get_usize(j, "position")?,
                    round: get_usize(j, "round")?,
                    block: get_hex(j, "block")?,
                    ck: get_hex(j, "ck")?,
                    rng: get_u128_pair(j, "rng")?,
                    docs,
                    z: get_z(j, "z")?,
                    dt: get_dt(j, "dt")?,
                })
            }
            "result" => Message::Result(ResultMsg {
                position: get_usize(j, "position")?,
                tokens: get_u64_str(j, "tokens")?,
                host_secs: get_f64(j, "host_secs")?,
                block: get_hex(j, "block")?,
                ck: get_hex(j, "ck")?,
                rng: get_u128_pair(j, "rng")?,
                z: get_z(j, "z")?,
                dt: get_dt(j, "dt")?,
            }),
            other => bail!("unknown protocol message type {other:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_round_trips_and_rejects_garbage() {
        let bytes: Vec<u8> = (0..=255u8).collect();
        assert_eq!(hex_decode(&hex_encode(&bytes)).unwrap(), bytes);
        assert_eq!(hex_encode(&[]), "");
        assert!(hex_decode("abc").is_err(), "odd length");
        assert!(hex_decode("zz").is_err(), "non-hex");
    }

    #[test]
    fn rng_state_survives_the_json_number_precision_wall() {
        // A PCG64 state uses all 128 bits; Json::Num would destroy it.
        let m = Message::Task(TaskMsg {
            position: 0,
            round: 0,
            block: vec![],
            ck: vec![],
            rng: (u128::MAX - 12345, (1u128 << 100) | 1),
            docs: vec![],
            z: vec![],
            dt: vec![],
        });
        assert_eq!(Message::from_json(&m.to_json()).unwrap(), m);
    }

    #[test]
    fn unknown_type_is_a_typed_error() {
        let j = Json::parse(r#"{"type":"warp"}"#).unwrap();
        let err = Message::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("warp"), "{err}");
    }
}
