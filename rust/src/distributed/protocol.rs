//! The master↔worker message vocabulary: JSON control plane, binary
//! delta data plane.
//!
//! Every message travels as one `serve::wire` frame. Control messages
//! (register/init/ready/shutdown/bye) and the `dist.delta = off`
//! full-state protocol ride JSON frames; with deltas on (the default)
//! the task/result hot path rides **binary frames** carrying
//! `model::wire` bytes directly — no hex-in-JSON doubling. The
//! conversation is a strict state machine per connection:
//!
//! ```text
//!  worker                         master
//!    │ ── register ──────────────► │   (once, at connect)
//!    │ ◄────────────────── init ── │   corpus recipe + hyperparameters
//!    │ ── ready{corpus_fp} ──────► │   fingerprints must agree
//!    │                             │
//!    │ ◄── task (full @ epoch) ─── │ ┐ first contact / epoch bump:
//!    │ ── result Δ ──────────────► │ ┘ full state out, sparse deltas back
//!    │ ◄── task Δ (epoch) ──────── │ ┐ steady state: block + C_k Δ + RNG
//!    │ ── result Δ ──────────────► │ ┘ out, sparse deltas back
//!    │          …                  │
//!    │ ◄────────────── shutdown ── │
//!    │ ── bye ───────────────────► │   then both sides close
//! ```
//!
//! **Epochs.** A worker's resident shard state (`docs`, `z`, `dt`, its
//! `C_k` snapshot) is only patchable by a delta if both sides agree on
//! the base. The master stamps every task with its current `epoch` and
//! bumps it on *any* event that could desynchronize residents — roster
//! change, rotation reassignment, reap, degraded round — after which
//! each position's first task ships full again. A worker receiving a
//! delta task whose epoch does not match its resident state refuses it
//! with the typed [`MpldaError::StaleEpoch`] rather than sampling
//! against a stale base; the master applies the same check to result
//! epochs. Over-bumping is correctness-neutral (it costs one full
//! resend), which is what makes the fault path safe by construction.
//!
//! **Numbers on the wire.** `serve::json` renders `f64` and integers are
//! exact only up to 2^53, so in JSON anything wider rides as a decimal
//! *string*: the two `u128` halves of a PCG64 state, and `u64` values
//! (fingerprints, epochs). Binary frames have no such wall — varints
//! and little-endian fixed fields throughout, sharing `model::wire`'s
//! primitives and its hostile-input discipline: every claimed count is
//! bounded by the remaining buffer before any allocation trusts it.
//!
//! **Why results still ship the mutated doc state every round?** The
//! master stays the single authority over `z`, `C_d^k`, worker RNG
//! streams and `C_k` snapshots; workers are pure compute plus a cache.
//! A result carries everything the kernel wrote (as deltas against the
//! task's base, which the master also holds) — which is what keeps the
//! distributed trajectory *bitwise* equal to the simulated one and makes
//! worker death recoverable by construction: a corpse holds no state the
//! master does not already have, except the one uncommitted round the
//! lease-timeout protocol is designed to sacrifice.

use anyhow::{bail, Context, Result};

use crate::config::{CorpusConfig, SamplerKind};
use crate::error::MpldaError;
use crate::model::wire::{get_varint, put_varint};
use crate::serve::json::Json;

/// One JSON-plane protocol message, either direction. `Json`-codable
/// losslessly; `tests/prop_protocol.rs` round-trips every variant
/// through the wire. The binary data plane is [`BinMsg`].
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker → master: first frame after connect.
    Register,
    /// Master → worker: everything needed to rebuild the shared world.
    Init(InitMsg),
    /// Worker → master: corpus rebuilt; `corpus_fp` proves it is the
    /// same corpus bit for bit.
    Ready {
        /// `model::checkpoint::corpus_fingerprint` of the rebuilt corpus.
        corpus_fp: u64,
    },
    /// Master → worker: one `(position, round)` full-state sampling task
    /// (the whole `dist.delta = off` protocol; the binary plane wraps
    /// the same struct for full resends).
    Task(TaskMsg),
    /// Worker → master: the completed task's full output state.
    Result(ResultMsg),
    /// Master → worker: training is over, close after `Bye`.
    Shutdown,
    /// Worker → master: acknowledges `Shutdown`; the socket closes next.
    Bye,
}

/// The master's handshake payload: a *recipe* for the corpus (workers
/// rebuild it locally — deterministic from its config — instead of
/// streaming gigabytes of tokens) plus every hyperparameter the sampler
/// kernel reads.
#[derive(Debug, Clone, PartialEq)]
pub struct InitMsg {
    /// Corpus recipe; `corpus::build` is deterministic in it.
    pub corpus: CorpusConfig,
    /// Topic count `K`.
    pub topics: usize,
    /// Dirichlet hyperparameter α.
    pub alpha: f64,
    /// Dirichlet hyperparameter β.
    pub beta: f64,
    /// Sampler kernel every task runs.
    pub sampler: SamplerKind,
    /// `train.alias_budget_mib` in bytes (mh-alias proposal tables).
    pub alias_budget_bytes: u64,
    /// Master-side corpus fingerprint the worker must reproduce.
    pub corpus_fp: u64,
    /// Wire frame cap both sides enforce after the handshake
    /// (`dist.max_frame_mib`, in bytes). The handshake itself always
    /// fits the default cap.
    pub max_frame_bytes: u64,
}

/// One round's full-state task for one rotation position: the leased
/// block, the position's `C_k` snapshot and RNG stream, and the
/// doc-shard state (assignments + live-order doc–topic entries, one row
/// per doc of `docs`, in `docs` order).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskMsg {
    /// Rotation position this task computes.
    pub position: usize,
    /// Round index within the iteration (diagnostics only).
    pub round: usize,
    /// Master epoch this task belongs to; the worker stamps its
    /// resident state with it and later deltas must match it.
    pub epoch: u64,
    /// `model::wire::encode_block` bytes of the leased block.
    pub block: Vec<u8>,
    /// `model::wire::encode_totals` bytes of the position's `C_k`.
    pub ck: Vec<u8>,
    /// Raw PCG64 `(state, inc)` of the position's RNG stream.
    pub rng: (u128, u128),
    /// The position's document shard (global doc ids, sorted).
    pub docs: Vec<u32>,
    /// Topic assignments, one row per doc of `docs`, in order.
    pub z: Vec<Vec<u32>>,
    /// Doc–topic counts in **live storage order** (descending by count —
    /// the samplers' walk order, so it must survive the trip verbatim),
    /// one row per doc of `docs`.
    pub dt: Vec<Vec<(u32, u32)>>,
    /// The master is tracing this round: the worker measures its phases
    /// and piggybacks [`PhaseSample`]s on the result.
    pub trace: bool,
}

/// A completed task: every piece of state the kernel mutated, shipped
/// back whole (the `dist.delta = off` reply).
#[derive(Debug, Clone, PartialEq)]
pub struct ResultMsg {
    /// Rotation position this result answers.
    pub position: usize,
    /// Epoch echoed from the task; the master rejects stale echoes.
    pub epoch: u64,
    /// Tokens sampled.
    pub tokens: u64,
    /// Thread CPU seconds the kernel took (drives the simulated clocks;
    /// never model state).
    pub host_secs: f64,
    /// Updated block bytes (`model::wire::encode_block`).
    pub block: Vec<u8>,
    /// Updated `C_k` snapshot bytes.
    pub ck: Vec<u8>,
    /// RNG stream position after the round.
    pub rng: (u128, u128),
    /// Updated assignments, rows matching the task's `docs` order.
    pub z: Vec<Vec<u32>>,
    /// Updated doc–topic counts, live order, rows matching `docs`.
    pub dt: Vec<Vec<(u32, u32)>>,
    /// Piggybacked phase timings; empty unless the task set `trace`.
    pub phases: Vec<PhaseSample>,
}

/// The steady-state task: position/round/epoch routing, the RNG stream,
/// the **full** leased block (rotation hands each position a different
/// block every round, so there is no resident base to delta against) and
/// the sparse `C_k` delta from the worker's post-round snapshot to the
/// master's freshly synced one. The doc shard does not ride at all —
/// it is resident on the worker at this epoch.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskDeltaMsg {
    /// Rotation position this task computes.
    pub position: usize,
    /// Round index within the iteration (diagnostics only).
    pub round: usize,
    /// Master epoch; must match the worker's resident state exactly.
    pub epoch: u64,
    /// Raw PCG64 `(state, inc)` of the position's RNG stream.
    pub rng: (u128, u128),
    /// `model::wire::encode_block` bytes of the leased block.
    pub block: Vec<u8>,
    /// `model::wire::encode_totals_delta` bytes: worker's resident
    /// `C_k` → the round's synced snapshot (empty delta when
    /// `coord.ck_sync` skipped the sync this round).
    pub ck_delta: Vec<u8>,
    /// The master is tracing this round: the worker measures its phases
    /// and piggybacks [`PhaseSample`]s on the result.
    pub trace: bool,
}

/// One document row's assignment update inside a delta result.
#[derive(Debug, Clone, PartialEq)]
pub enum ZRowDiff {
    /// The round left every assignment in this row unchanged.
    Unchanged,
    /// Most slots changed — the full row is cheaper than a diff.
    Full(Vec<u32>),
    /// Sparse update: `(slot, new_topic)` pairs, slots strictly
    /// increasing.
    Sparse(Vec<(u32, u32)>),
}

/// The steady-state reply: sparse deltas for the block and `C_k`
/// (against the task's base, which the master also holds), per-row
/// assignment diffs, and the doc–topic rows verbatim (their **live
/// order** is a function of the full sampling history — it cannot be
/// re-derived master-side, so it ships whole; rows are tiny,
/// `nnz ≤ min(doc_len, K)`).
#[derive(Debug, Clone, PartialEq)]
pub struct ResultDeltaMsg {
    /// Rotation position this result answers.
    pub position: usize,
    /// Epoch echoed from the task; the master rejects stale echoes.
    pub epoch: u64,
    /// Tokens sampled.
    pub tokens: u64,
    /// Thread CPU seconds the kernel took.
    pub host_secs: f64,
    /// RNG stream position after the round.
    pub rng: (u128, u128),
    /// `model::wire::encode_block_delta` bytes, task block → mutated
    /// block.
    pub block_delta: Vec<u8>,
    /// `model::wire::encode_totals_delta` bytes, task `C_k` → the
    /// worker's post-round snapshot.
    pub ck_delta: Vec<u8>,
    /// Assignment updates, one entry per doc of the shard, in `docs`
    /// order.
    pub z: Vec<ZRowDiff>,
    /// Doc–topic counts in live storage order, one row per doc.
    pub dt: Vec<Vec<(u32, u32)>>,
    /// Piggybacked phase timings; empty unless the task set `trace`.
    pub phases: Vec<PhaseSample>,
}

/// One binary-plane message. Encoded as a 1-byte tag + body; travels in
/// a `serve::wire` **binary** frame (top-bit length prefix).
#[derive(Debug, Clone, PartialEq)]
pub enum BinMsg {
    /// Full-state task (first contact at an epoch / post-bump resend).
    TaskFull(TaskMsg),
    /// Steady-state delta task.
    TaskDelta(TaskDeltaMsg),
    /// The reply to either binary task kind.
    ResultDelta(ResultDeltaMsg),
}

/// Which worker-side phase a piggybacked timing covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WirePhase {
    /// Task frame / block decoding.
    Decode,
    /// The sampling kernel.
    Sample,
    /// Result / delta encoding.
    Encode,
}

impl WirePhase {
    /// Stable wire id.
    pub fn id(self) -> u64 {
        match self {
            WirePhase::Decode => 0,
            WirePhase::Sample => 1,
            WirePhase::Encode => 2,
        }
    }

    /// Decode a wire id; typed error on unknown values.
    pub fn from_id(id: u64) -> Result<WirePhase> {
        Ok(match id {
            0 => WirePhase::Decode,
            1 => WirePhase::Sample,
            2 => WirePhase::Encode,
            other => bail!("unknown phase id {other}"),
        })
    }

    /// Span name in the merged cluster trace (the driver's phase
    /// vocabulary: `wire_decode` / `sample` / `wire_encode`).
    pub fn name(self) -> &'static str {
        match self {
            WirePhase::Decode => "wire_decode",
            WirePhase::Sample => "sample",
            WirePhase::Encode => "wire_encode",
        }
    }
}

/// One worker-side phase timing, µs offsets relative to task receipt.
///
/// Rides **out-of-band** on result frames when the master asked for
/// tracing (`trace` flag on the task): the master re-bases the offsets
/// onto its own clock at task-send time and merges them into the
/// cluster trace. Model bytes, RNG streams and the simulated clock
/// never read these values, so tracing on vs off is digest-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseSample {
    /// Which phase this covers.
    pub phase: WirePhase,
    /// Start offset since task receipt (µs).
    pub start_us: u64,
    /// Duration (µs).
    pub dur_us: u64,
}

/// Typed gate shared by both sides of the delta protocol: a message at
/// `got` is only applicable when the receiver's resident state is at
/// exactly that epoch.
pub fn require_epoch(position: usize, got: u64, have: Option<u64>) -> Result<()> {
    if have != Some(got) {
        return Err(MpldaError::StaleEpoch { position, got, have }.into());
    }
    Ok(())
}

// ---------------------------------------------------------------------
// JSON encoding helpers
// ---------------------------------------------------------------------

/// Hex-encode binary payload bytes for a JSON string field.
pub fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        s.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
    }
    s
}

/// Decode [`hex_encode`] output; typed errors on odd length or non-hex.
pub fn hex_decode(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        bail!("hex payload has odd length {}", s.len());
    }
    let digits = s.as_bytes();
    let mut out = Vec::with_capacity(s.len() / 2);
    for pair in digits.chunks_exact(2) {
        let hi = (pair[0] as char).to_digit(16).context("non-hex byte in payload")?;
        let lo = (pair[1] as char).to_digit(16).context("non-hex byte in payload")?;
        out.push(((hi << 4) | lo) as u8);
    }
    Ok(out)
}

/// `u64` as a decimal JSON string (`Json::Num` is exact only to 2^53).
fn u64_str(v: u64) -> Json {
    Json::str(v.to_string())
}

fn get_u64_str(j: &Json, key: &str) -> Result<u64> {
    j.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("missing string field {key:?}"))?
        .parse::<u64>()
        .with_context(|| format!("field {key:?} is not a u64"))
}

fn get_u128_pair(j: &Json, key: &str) -> Result<(u128, u128)> {
    let arr = j
        .get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing array field {key:?}"))?;
    if arr.len() != 2 {
        bail!("field {key:?} must be a [state, inc] pair, got {} entries", arr.len());
    }
    let part = |i: usize| -> Result<u128> {
        arr[i]
            .as_str()
            .with_context(|| format!("field {key:?}[{i}] is not a string"))?
            .parse::<u128>()
            .with_context(|| format!("field {key:?}[{i}] is not a u128"))
    };
    Ok((part(0)?, part(1)?))
}

fn rng_json((state, inc): (u128, u128)) -> Json {
    Json::Arr(vec![Json::str(state.to_string()), Json::str(inc.to_string())])
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_u64)
        .with_context(|| format!("missing integer field {key:?}"))
        .map(|v| v as usize)
}

fn get_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing number field {key:?}"))
}

fn get_str<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("missing string field {key:?}"))
}

fn get_hex(j: &Json, key: &str) -> Result<Vec<u8>> {
    hex_decode(get_str(j, key)?).with_context(|| format!("decoding hex field {key:?}"))
}

fn z_json(z: &[Vec<u32>]) -> Json {
    Json::Arr(
        z.iter()
            .map(|row| Json::Arr(row.iter().map(|&t| Json::num(t as f64)).collect()))
            .collect(),
    )
}

fn get_z(j: &Json, key: &str, rows_expected: usize) -> Result<Vec<Vec<u32>>> {
    let rows = j
        .get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing array field {key:?}"))?;
    // Bound the row count by the shard size *before* converting rows —
    // a hostile frame must not get row allocations for docs the shard
    // does not have (same guard discipline as `model::wire`).
    if rows.len() != rows_expected {
        bail!("field {key:?} has {} rows, shard has {rows_expected} docs", rows.len());
    }
    rows.iter()
        .map(|row| {
            row.as_arr()
                .context("assignment row is not an array")?
                .iter()
                .map(|t| {
                    let v = t.as_u64().context("assignment is not a non-negative integer")?;
                    u32::try_from(v).context("assignment exceeds u32")
                })
                .collect()
        })
        .collect()
}

/// Doc–topic rows as flat `[t0,c0,t1,c1,…]` arrays — half the JSON nodes
/// of nested pairs, and the flat order *is* the live storage order.
fn dt_json(dt: &[Vec<(u32, u32)>]) -> Json {
    Json::Arr(
        dt.iter()
            .map(|row| {
                let mut flat = Vec::with_capacity(row.len() * 2);
                for &(t, c) in row {
                    flat.push(Json::num(t as f64));
                    flat.push(Json::num(c as f64));
                }
                Json::Arr(flat)
            })
            .collect(),
    )
}

fn get_dt(j: &Json, key: &str, rows_expected: usize) -> Result<Vec<Vec<(u32, u32)>>> {
    let rows = j
        .get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing array field {key:?}"))?;
    if rows.len() != rows_expected {
        bail!("field {key:?} has {} rows, shard has {rows_expected} docs", rows.len());
    }
    rows.iter()
        .map(|row| {
            let flat = row.as_arr().context("doc-topic row is not an array")?;
            if flat.len() % 2 != 0 {
                bail!("doc-topic row has odd length {}", flat.len());
            }
            flat.chunks_exact(2)
                .map(|pair| {
                    let t = pair[0].as_u64().context("doc-topic topic is not an integer")?;
                    let c = pair[1].as_u64().context("doc-topic count is not an integer")?;
                    Ok((
                        u32::try_from(t).context("topic exceeds u32")?,
                        u32::try_from(c).context("count exceeds u32")?,
                    ))
                })
                .collect()
        })
        .collect()
}

/// Phase samples as one flat `[id, start, dur, …]` array.
fn phases_json(phases: &[PhaseSample]) -> Json {
    let mut flat = Vec::with_capacity(phases.len() * 3);
    for p in phases {
        flat.push(Json::num(p.phase.id() as f64));
        flat.push(Json::num(p.start_us as f64));
        flat.push(Json::num(p.dur_us as f64));
    }
    Json::Arr(flat)
}

fn get_phases(j: &Json) -> Result<Vec<PhaseSample>> {
    let Some(flat) = j.get("phases").and_then(Json::as_arr) else {
        return Ok(Vec::new());
    };
    if flat.len() % 3 != 0 {
        bail!("phases array length {} is not a multiple of 3", flat.len());
    }
    flat.chunks_exact(3)
        .map(|t| {
            let num = |i: usize, what: &str| {
                t[i].as_u64().with_context(|| format!("phase {what} is not an integer"))
            };
            Ok(PhaseSample {
                phase: WirePhase::from_id(num(0, "id")?)?,
                start_us: num(1, "start")?,
                dur_us: num(2, "duration")?,
            })
        })
        .collect()
}

fn get_docs(j: &Json, key: &str) -> Result<Vec<u32>> {
    j.get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing array field {key:?}"))?
        .iter()
        .map(|d| {
            let v = d.as_u64().context("doc id is not a non-negative integer")?;
            u32::try_from(v).context("doc id exceeds u32")
        })
        .collect()
}

impl Message {
    /// The `"type"` tag this message carries on the wire.
    pub fn kind(&self) -> &'static str {
        match self {
            Message::Register => "register",
            Message::Init(_) => "init",
            Message::Ready { .. } => "ready",
            Message::Task(_) => "task",
            Message::Result(_) => "result",
            Message::Shutdown => "shutdown",
            Message::Bye => "bye",
        }
    }

    /// Encode for one wire frame.
    pub fn to_json(&self) -> Json {
        let tag = ("type".to_string(), Json::str(self.kind()));
        match self {
            Message::Register | Message::Shutdown | Message::Bye => Json::Obj(vec![tag]),
            Message::Ready { corpus_fp } => {
                Json::Obj(vec![tag, ("corpus_fp".into(), u64_str(*corpus_fp))])
            }
            Message::Init(m) => Json::Obj(vec![
                tag,
                ("corpus_preset".into(), Json::str(&m.corpus.preset)),
                ("corpus_vocab".into(), Json::num(m.corpus.vocab as f64)),
                ("corpus_docs".into(), Json::num(m.corpus.docs as f64)),
                ("corpus_avg_doc_len".into(), Json::num(m.corpus.avg_doc_len as f64)),
                ("corpus_zipf_s".into(), Json::num(m.corpus.zipf_s)),
                ("corpus_gen_topics".into(), Json::num(m.corpus.gen_topics as f64)),
                ("corpus_gen_alpha".into(), Json::num(m.corpus.gen_alpha)),
                ("corpus_gen_beta".into(), Json::num(m.corpus.gen_beta)),
                ("corpus_bigram".into(), Json::Bool(m.corpus.bigram)),
                ("corpus_path".into(), Json::str(&m.corpus.path)),
                ("corpus_seed".into(), u64_str(m.corpus.seed)),
                ("topics".into(), Json::num(m.topics as f64)),
                ("alpha".into(), Json::num(m.alpha)),
                ("beta".into(), Json::num(m.beta)),
                ("sampler".into(), Json::str(m.sampler.name())),
                ("alias_budget_bytes".into(), u64_str(m.alias_budget_bytes)),
                ("corpus_fp".into(), u64_str(m.corpus_fp)),
                ("max_frame_bytes".into(), u64_str(m.max_frame_bytes)),
            ]),
            Message::Task(m) => {
                let mut fields = vec![
                    tag,
                    ("position".into(), Json::num(m.position as f64)),
                    ("round".into(), Json::num(m.round as f64)),
                    ("epoch".into(), u64_str(m.epoch)),
                    ("block".into(), Json::str(hex_encode(&m.block))),
                    ("ck".into(), Json::str(hex_encode(&m.ck))),
                    ("rng".into(), rng_json(m.rng)),
                    (
                        "docs".into(),
                        Json::Arr(m.docs.iter().map(|&d| Json::num(d as f64)).collect()),
                    ),
                    ("z".into(), z_json(&m.z)),
                    ("dt".into(), dt_json(&m.dt)),
                ];
                // Absent unless set, keeping untraced frames byte-stable.
                if m.trace {
                    fields.push(("trace".into(), Json::Bool(true)));
                }
                Json::Obj(fields)
            }
            Message::Result(m) => {
                let mut fields = vec![
                    tag,
                    ("position".into(), Json::num(m.position as f64)),
                    ("epoch".into(), u64_str(m.epoch)),
                    ("tokens".into(), u64_str(m.tokens)),
                    ("host_secs".into(), Json::num(m.host_secs)),
                    ("block".into(), Json::str(hex_encode(&m.block))),
                    ("ck".into(), Json::str(hex_encode(&m.ck))),
                    ("rng".into(), rng_json(m.rng)),
                    ("docs".into(), Json::num(m.z.len() as f64)),
                    ("z".into(), z_json(&m.z)),
                    ("dt".into(), dt_json(&m.dt)),
                ];
                if !m.phases.is_empty() {
                    fields.push(("phases".into(), phases_json(&m.phases)));
                }
                Json::Obj(fields)
            }
        }
    }

    /// Decode one wire frame; typed errors on unknown tags or malformed
    /// fields — never a panic (the peer controls these bytes).
    pub fn from_json(j: &Json) -> Result<Message> {
        let kind = get_str(j, "type")?;
        Ok(match kind {
            "register" => Message::Register,
            "shutdown" => Message::Shutdown,
            "bye" => Message::Bye,
            "ready" => Message::Ready { corpus_fp: get_u64_str(j, "corpus_fp")? },
            "init" => {
                let corpus = CorpusConfig {
                    preset: get_str(j, "corpus_preset")?.to_string(),
                    vocab: get_usize(j, "corpus_vocab")?,
                    docs: get_usize(j, "corpus_docs")?,
                    avg_doc_len: get_usize(j, "corpus_avg_doc_len")?,
                    zipf_s: get_f64(j, "corpus_zipf_s")?,
                    gen_topics: get_usize(j, "corpus_gen_topics")?,
                    gen_alpha: get_f64(j, "corpus_gen_alpha")?,
                    gen_beta: get_f64(j, "corpus_gen_beta")?,
                    bigram: matches!(j.get("corpus_bigram"), Some(Json::Bool(true))),
                    path: get_str(j, "corpus_path")?.to_string(),
                    seed: get_u64_str(j, "corpus_seed")?,
                };
                Message::Init(InitMsg {
                    corpus,
                    topics: get_usize(j, "topics")?,
                    alpha: get_f64(j, "alpha")?,
                    beta: get_f64(j, "beta")?,
                    sampler: SamplerKind::parse(get_str(j, "sampler")?)?,
                    alias_budget_bytes: get_u64_str(j, "alias_budget_bytes")?,
                    corpus_fp: get_u64_str(j, "corpus_fp")?,
                    max_frame_bytes: get_u64_str(j, "max_frame_bytes")?,
                })
            }
            "task" => {
                let docs = get_docs(j, "docs")?;
                let ndocs = docs.len();
                Message::Task(TaskMsg {
                    position: get_usize(j, "position")?,
                    round: get_usize(j, "round")?,
                    epoch: get_u64_str(j, "epoch")?,
                    block: get_hex(j, "block")?,
                    ck: get_hex(j, "ck")?,
                    rng: get_u128_pair(j, "rng")?,
                    docs,
                    z: get_z(j, "z", ndocs)?,
                    dt: get_dt(j, "dt", ndocs)?,
                    trace: matches!(j.get("trace"), Some(Json::Bool(true))),
                })
            }
            "result" => {
                // Results carry no doc list; the row count rides as a
                // scalar so `z`/`dt` conversion is bounded before any
                // row materializes (the master re-checks it against the
                // shard when applying).
                let ndocs = get_usize(j, "docs")?;
                Message::Result(ResultMsg {
                    position: get_usize(j, "position")?,
                    epoch: get_u64_str(j, "epoch")?,
                    tokens: get_u64_str(j, "tokens")?,
                    host_secs: get_f64(j, "host_secs")?,
                    block: get_hex(j, "block")?,
                    ck: get_hex(j, "ck")?,
                    rng: get_u128_pair(j, "rng")?,
                    z: get_z(j, "z", ndocs)?,
                    dt: get_dt(j, "dt", ndocs)?,
                    phases: get_phases(j)?,
                })
            }
            other => bail!("unknown protocol message type {other:?}"),
        })
    }
}

// ---------------------------------------------------------------------
// Binary codec
// ---------------------------------------------------------------------

const TAG_TASK_FULL: u8 = 1;
const TAG_TASK_DELTA: u8 = 2;
const TAG_RESULT_DELTA: u8 = 3;

fn put_u128(buf: &mut Vec<u8>, v: u128) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_u128(buf: &[u8], pos: &mut usize) -> Result<u128> {
    let end = pos.checked_add(16).filter(|&e| e <= buf.len()).context("u128 truncated")?;
    let v = u128::from_le_bytes(buf[*pos..end].try_into().unwrap());
    *pos = end;
    Ok(v)
}

fn put_rng(buf: &mut Vec<u8>, (state, inc): (u128, u128)) {
    put_u128(buf, state);
    put_u128(buf, inc);
}

fn get_rng(buf: &[u8], pos: &mut usize) -> Result<(u128, u128)> {
    Ok((get_u128(buf, pos)?, get_u128(buf, pos)?))
}

fn put_bytes(buf: &mut Vec<u8>, b: &[u8]) {
    put_varint(buf, b.len() as u64);
    buf.extend_from_slice(b);
}

fn get_bytes(buf: &[u8], pos: &mut usize) -> Result<Vec<u8>> {
    let len = get_varint(buf, pos)? as usize;
    if len > buf.len() - *pos {
        bail!("payload claims {len} bytes but only {} remain", buf.len() - *pos);
    }
    let out = buf[*pos..*pos + len].to_vec();
    *pos += len;
    Ok(out)
}

fn get_u32v(buf: &[u8], pos: &mut usize) -> Result<u32> {
    u32::try_from(get_varint(buf, pos)?).context("value exceeds u32")
}

fn get_u8(buf: &[u8], pos: &mut usize) -> Result<u8> {
    let b = *buf.get(*pos).context("byte field truncated")?;
    *pos += 1;
    Ok(b)
}

fn get_trace_flag(buf: &[u8], pos: &mut usize) -> Result<bool> {
    match get_u8(buf, pos)? {
        0 => Ok(false),
        1 => Ok(true),
        other => bail!("trace flag must be 0 or 1, got {other}"),
    }
}

fn put_phases(buf: &mut Vec<u8>, phases: &[PhaseSample]) {
    put_varint(buf, phases.len() as u64);
    for p in phases {
        put_varint(buf, p.phase.id());
        put_varint(buf, p.start_us);
        put_varint(buf, p.dur_us);
    }
}

fn get_phases_bin(buf: &[u8], pos: &mut usize) -> Result<Vec<PhaseSample>> {
    let n = get_varint(buf, pos)?;
    let n = bounded_count(buf, *pos, n, 3, "phase sample list")?;
    let mut phases = Vec::with_capacity(n);
    for _ in 0..n {
        phases.push(PhaseSample {
            phase: WirePhase::from_id(get_varint(buf, pos)?)?,
            start_us: get_varint(buf, pos)?,
            dur_us: get_varint(buf, pos)?,
        });
    }
    Ok(phases)
}

/// Bound a claimed element count by the remaining bytes, given the
/// minimum wire cost per element, *before* allocating for it.
fn bounded_count(buf: &[u8], pos: usize, n: u64, min_bytes: usize, what: &str) -> Result<usize> {
    let remain = buf.len() - pos;
    if n as usize > remain / min_bytes.max(1) {
        bail!("{what} claims {n} entries but only {remain} bytes remain");
    }
    Ok(n as usize)
}

fn put_dt_rows(buf: &mut Vec<u8>, dt: &[Vec<(u32, u32)>]) {
    for row in dt {
        put_varint(buf, row.len() as u64);
        for &(t, c) in row {
            // Live order is arbitrary, so topics ride raw, not
            // gap-coded.
            put_varint(buf, t as u64);
            put_varint(buf, c as u64);
        }
    }
}

fn get_dt_rows(buf: &[u8], pos: &mut usize, nrows: usize) -> Result<Vec<Vec<(u32, u32)>>> {
    let mut dt = Vec::with_capacity(nrows);
    for _ in 0..nrows {
        let n = get_varint(buf, pos)?;
        let n = bounded_count(buf, *pos, n, 2, "doc-topic row")?;
        let mut row = Vec::with_capacity(n);
        for _ in 0..n {
            let t = get_u32v(buf, pos)?;
            let c = get_u32v(buf, pos)?;
            row.push((t, c));
        }
        dt.push(row);
    }
    Ok(dt)
}

impl BinMsg {
    /// Encode as one binary frame body.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(64);
        match self {
            BinMsg::TaskFull(m) => {
                buf.push(TAG_TASK_FULL);
                put_varint(&mut buf, m.position as u64);
                put_varint(&mut buf, m.round as u64);
                put_varint(&mut buf, m.epoch);
                put_rng(&mut buf, m.rng);
                put_bytes(&mut buf, &m.block);
                put_bytes(&mut buf, &m.ck);
                put_varint(&mut buf, m.docs.len() as u64);
                for &d in &m.docs {
                    put_varint(&mut buf, d as u64);
                }
                for row in &m.z {
                    put_varint(&mut buf, row.len() as u64);
                    for &t in row {
                        put_varint(&mut buf, t as u64);
                    }
                }
                put_dt_rows(&mut buf, &m.dt);
                buf.push(m.trace as u8);
            }
            BinMsg::TaskDelta(m) => {
                buf.push(TAG_TASK_DELTA);
                put_varint(&mut buf, m.position as u64);
                put_varint(&mut buf, m.round as u64);
                put_varint(&mut buf, m.epoch);
                put_rng(&mut buf, m.rng);
                put_bytes(&mut buf, &m.block);
                put_bytes(&mut buf, &m.ck_delta);
                buf.push(m.trace as u8);
            }
            BinMsg::ResultDelta(m) => {
                buf.push(TAG_RESULT_DELTA);
                put_varint(&mut buf, m.position as u64);
                put_varint(&mut buf, m.epoch);
                put_varint(&mut buf, m.tokens);
                buf.extend_from_slice(&m.host_secs.to_le_bytes());
                put_rng(&mut buf, m.rng);
                put_bytes(&mut buf, &m.block_delta);
                put_bytes(&mut buf, &m.ck_delta);
                put_varint(&mut buf, m.z.len() as u64);
                for row in &m.z {
                    match row {
                        ZRowDiff::Unchanged => put_varint(&mut buf, 0),
                        ZRowDiff::Full(topics) => {
                            put_varint(&mut buf, 1);
                            put_varint(&mut buf, topics.len() as u64);
                            for &t in topics {
                                put_varint(&mut buf, t as u64);
                            }
                        }
                        ZRowDiff::Sparse(pairs) => {
                            put_varint(&mut buf, pairs.len() as u64 + 2);
                            for &(slot, topic) in pairs {
                                put_varint(&mut buf, slot as u64);
                                put_varint(&mut buf, topic as u64);
                            }
                        }
                    }
                }
                put_dt_rows(&mut buf, &m.dt);
                put_phases(&mut buf, &m.phases);
            }
        }
        buf
    }

    /// Decode one binary frame body. Typed errors throughout, never a
    /// panic; every claimed count is bounded by the remaining bytes
    /// before any allocation trusts it, and `z`/`dt` row counts are the
    /// (bounded) doc count itself — a frame cannot claim more rows than
    /// docs.
    pub fn decode(buf: &[u8]) -> Result<BinMsg> {
        let Some(&tag) = buf.first() else { bail!("empty binary protocol frame") };
        let mut pos = 1usize;
        let msg = match tag {
            TAG_TASK_FULL => {
                let position = get_varint(buf, &mut pos)? as usize;
                let round = get_varint(buf, &mut pos)? as usize;
                let epoch = get_varint(buf, &mut pos)?;
                let rng = get_rng(buf, &mut pos)?;
                let block = get_bytes(buf, &mut pos)?;
                let ck = get_bytes(buf, &mut pos)?;
                let n = get_varint(buf, &mut pos)?;
                let ndocs = bounded_count(buf, pos, n, 1, "doc list")?;
                let mut docs = Vec::with_capacity(ndocs);
                for _ in 0..ndocs {
                    docs.push(get_u32v(buf, &mut pos)?);
                }
                let mut z = Vec::with_capacity(ndocs);
                for _ in 0..ndocs {
                    let len = get_varint(buf, &mut pos)?;
                    let len = bounded_count(buf, pos, len, 1, "assignment row")?;
                    let mut row = Vec::with_capacity(len);
                    for _ in 0..len {
                        row.push(get_u32v(buf, &mut pos)?);
                    }
                    z.push(row);
                }
                let dt = get_dt_rows(buf, &mut pos, ndocs)?;
                let trace = get_trace_flag(buf, &mut pos)?;
                BinMsg::TaskFull(TaskMsg {
                    position,
                    round,
                    epoch,
                    block,
                    ck,
                    rng,
                    docs,
                    z,
                    dt,
                    trace,
                })
            }
            TAG_TASK_DELTA => {
                let position = get_varint(buf, &mut pos)? as usize;
                let round = get_varint(buf, &mut pos)? as usize;
                let epoch = get_varint(buf, &mut pos)?;
                let rng = get_rng(buf, &mut pos)?;
                let block = get_bytes(buf, &mut pos)?;
                let ck_delta = get_bytes(buf, &mut pos)?;
                let trace = get_trace_flag(buf, &mut pos)?;
                BinMsg::TaskDelta(TaskDeltaMsg {
                    position,
                    round,
                    epoch,
                    rng,
                    block,
                    ck_delta,
                    trace,
                })
            }
            TAG_RESULT_DELTA => {
                let position = get_varint(buf, &mut pos)? as usize;
                let epoch = get_varint(buf, &mut pos)?;
                let tokens = get_varint(buf, &mut pos)?;
                let end = pos
                    .checked_add(8)
                    .filter(|&e| e <= buf.len())
                    .context("host_secs truncated")?;
                let host_secs = f64::from_le_bytes(buf[pos..end].try_into().unwrap());
                pos = end;
                let rng = get_rng(buf, &mut pos)?;
                let block_delta = get_bytes(buf, &mut pos)?;
                let ck_delta = get_bytes(buf, &mut pos)?;
                let n = get_varint(buf, &mut pos)?;
                let nrows = bounded_count(buf, pos, n, 1, "assignment diff list")?;
                let mut z = Vec::with_capacity(nrows);
                for _ in 0..nrows {
                    let t = get_varint(buf, &mut pos)?;
                    z.push(match t {
                        0 => ZRowDiff::Unchanged,
                        1 => {
                            let len = get_varint(buf, &mut pos)?;
                            let len = bounded_count(buf, pos, len, 1, "assignment row")?;
                            let mut row = Vec::with_capacity(len);
                            for _ in 0..len {
                                row.push(get_u32v(buf, &mut pos)?);
                            }
                            ZRowDiff::Full(row)
                        }
                        t => {
                            let np = bounded_count(buf, pos, t - 2, 2, "assignment diff")?;
                            let mut pairs = Vec::with_capacity(np);
                            let mut prev: Option<u32> = None;
                            for _ in 0..np {
                                let slot = get_u32v(buf, &mut pos)?;
                                if prev.is_some_and(|p| slot <= p) {
                                    bail!("assignment diff slots are not strictly increasing");
                                }
                                prev = Some(slot);
                                let topic = get_u32v(buf, &mut pos)?;
                                pairs.push((slot, topic));
                            }
                            ZRowDiff::Sparse(pairs)
                        }
                    });
                }
                let dt = get_dt_rows(buf, &mut pos, nrows)?;
                let phases = get_phases_bin(buf, &mut pos)?;
                BinMsg::ResultDelta(ResultDeltaMsg {
                    position,
                    epoch,
                    tokens,
                    host_secs,
                    rng,
                    block_delta,
                    ck_delta,
                    z,
                    dt,
                    phases,
                })
            }
            other => bail!("unknown binary protocol tag {other}"),
        };
        if pos != buf.len() {
            bail!("trailing bytes after binary protocol message");
        }
        Ok(msg)
    }
}

/// Build the per-row assignment update for one doc: `Unchanged` when
/// nothing moved, a sparse `(slot, new_topic)` list when few slots did,
/// the full row once a diff would cost more than shipping it whole
/// (each sparse pair is two varints to a full row's one).
pub fn z_row_diff(before: &[u32], after: &[u32]) -> ZRowDiff {
    debug_assert_eq!(before.len(), after.len());
    let changed: Vec<(u32, u32)> = before
        .iter()
        .zip(after)
        .enumerate()
        .filter(|(_, (b, a))| b != a)
        .map(|(i, (_, &a))| (i as u32, a))
        .collect();
    if changed.is_empty() {
        ZRowDiff::Unchanged
    } else if changed.len() * 2 >= after.len() {
        ZRowDiff::Full(after.to_vec())
    } else {
        ZRowDiff::Sparse(changed)
    }
}

/// Apply a [`ZRowDiff`] onto the resident row in place. Typed errors on
/// length/slot mismatches (the peer controls these values).
pub fn apply_z_row_diff(row: &mut Vec<u32>, diff: &ZRowDiff) -> Result<()> {
    match diff {
        ZRowDiff::Unchanged => Ok(()),
        ZRowDiff::Full(topics) => {
            if topics.len() != row.len() {
                bail!("full assignment row has {} slots, doc has {}", topics.len(), row.len());
            }
            row.clone_from(topics);
            Ok(())
        }
        ZRowDiff::Sparse(pairs) => {
            for &(slot, topic) in pairs {
                let s = row
                    .get_mut(slot as usize)
                    .with_context(|| format!("assignment diff slot {slot} out of range"))?;
                *s = topic;
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_round_trips_and_rejects_garbage() {
        let bytes: Vec<u8> = (0..=255u8).collect();
        assert_eq!(hex_decode(&hex_encode(&bytes)).unwrap(), bytes);
        assert_eq!(hex_encode(&[]), "");
        assert!(hex_decode("abc").is_err(), "odd length");
        assert!(hex_decode("zz").is_err(), "non-hex");
    }

    #[test]
    fn rng_state_survives_the_json_number_precision_wall() {
        // A PCG64 state uses all 128 bits; Json::Num would destroy it.
        let m = Message::Task(TaskMsg {
            position: 0,
            round: 0,
            epoch: u64::MAX - 7,
            block: vec![],
            ck: vec![],
            rng: (u128::MAX - 12345, (1u128 << 100) | 1),
            docs: vec![],
            z: vec![],
            dt: vec![],
            trace: true,
        });
        assert_eq!(Message::from_json(&m.to_json()).unwrap(), m);
    }

    #[test]
    fn unknown_type_is_a_typed_error() {
        let j = Json::parse(r#"{"type":"warp"}"#).unwrap();
        let err = Message::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("warp"), "{err}");
    }

    #[test]
    fn json_task_row_counts_are_bounded_by_docs() {
        let m = Message::Task(TaskMsg {
            position: 1,
            round: 2,
            epoch: 3,
            block: vec![1, 2],
            ck: vec![3],
            rng: (4, 5),
            docs: vec![10, 11],
            z: vec![vec![0], vec![1, 2]],
            dt: vec![vec![(0, 1)], vec![(1, 2)]],
            trace: false,
        });
        let mut j = m.to_json();
        // Graft an extra z row: decode must refuse before converting.
        if let Json::Obj(fields) = &mut j {
            for (k, v) in fields.iter_mut() {
                if k == "z" {
                    if let Json::Arr(rows) = v {
                        rows.push(Json::Arr(vec![]));
                    }
                }
            }
        }
        let err = Message::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("shard has 2 docs"), "{err}");
    }

    fn sample_result_delta() -> ResultDeltaMsg {
        ResultDeltaMsg {
            position: 3,
            epoch: 9,
            tokens: 1234,
            host_secs: 0.25,
            rng: (u128::MAX - 1, 77),
            block_delta: vec![1, 2, 3],
            ck_delta: vec![4, 5],
            z: vec![
                ZRowDiff::Unchanged,
                ZRowDiff::Full(vec![7, 8, 9]),
                ZRowDiff::Sparse(vec![(0, 5), (4, 2)]),
            ],
            dt: vec![vec![(3, 2)], vec![(1, 1), (0, 4)], vec![]],
            phases: vec![
                PhaseSample { phase: WirePhase::Decode, start_us: 0, dur_us: 12 },
                PhaseSample { phase: WirePhase::Sample, start_us: 15, dur_us: 800 },
                PhaseSample { phase: WirePhase::Encode, start_us: 820, dur_us: 9 },
            ],
        }
    }

    #[test]
    fn bin_messages_roundtrip() {
        let msgs = [
            BinMsg::TaskFull(TaskMsg {
                position: 2,
                round: 1,
                epoch: 6,
                block: vec![9; 5],
                ck: vec![8; 3],
                rng: (1 << 90, 3),
                docs: vec![4, 7, 9],
                z: vec![vec![1, 2], vec![], vec![3]],
                dt: vec![vec![(1, 2)], vec![], vec![(3, 1), (0, 1)]],
                trace: true,
            }),
            BinMsg::TaskDelta(TaskDeltaMsg {
                position: 0,
                round: 4,
                epoch: 2,
                rng: (5, 6),
                block: vec![1],
                ck_delta: vec![],
                trace: false,
            }),
            BinMsg::ResultDelta(sample_result_delta()),
        ];
        for m in msgs {
            let enc = m.encode();
            assert_eq!(BinMsg::decode(&enc).unwrap(), m, "{m:?}");
        }
    }

    #[test]
    fn bin_decode_never_panics_on_truncation_or_garbage() {
        let enc = BinMsg::ResultDelta(sample_result_delta()).encode();
        for cut in 0..enc.len() {
            assert!(BinMsg::decode(&enc[..cut]).is_err(), "cut {cut}");
        }
        assert!(BinMsg::decode(&[]).is_err());
        assert!(BinMsg::decode(&[200, 1, 2]).is_err(), "unknown tag");
        let mut trailing = enc;
        trailing.push(0);
        assert!(BinMsg::decode(&trailing).is_err());
        // Hostile doc count: claims 2^40 docs in a few bytes.
        let mut buf = vec![TAG_TASK_FULL];
        put_varint(&mut buf, 0);
        put_varint(&mut buf, 0);
        put_varint(&mut buf, 0);
        put_rng(&mut buf, (0, 0));
        put_bytes(&mut buf, &[]);
        put_bytes(&mut buf, &[]);
        put_varint(&mut buf, 1 << 40);
        assert!(BinMsg::decode(&buf).is_err());
    }

    #[test]
    fn z_row_diff_picks_the_cheaper_encoding_and_applies_exactly() {
        let before = vec![1, 2, 3, 4, 5, 6];
        // One change → sparse.
        let mut after = before.clone();
        after[2] = 9;
        let d = z_row_diff(&before, &after);
        assert!(matches!(d, ZRowDiff::Sparse(ref p) if p.len() == 1));
        let mut row = before.clone();
        apply_z_row_diff(&mut row, &d).unwrap();
        assert_eq!(row, after);
        // Most slots changed → full.
        let after: Vec<u32> = before.iter().map(|t| t + 1).collect();
        let d = z_row_diff(&before, &after);
        assert!(matches!(d, ZRowDiff::Full(_)));
        let mut row = before.clone();
        apply_z_row_diff(&mut row, &d).unwrap();
        assert_eq!(row, after);
        // No change → unchanged.
        assert_eq!(z_row_diff(&before, &before), ZRowDiff::Unchanged);
        // Out-of-range slot is typed.
        let mut row = vec![0u32; 2];
        let err = apply_z_row_diff(&mut row, &ZRowDiff::Sparse(vec![(5, 1)]));
        assert!(err.is_err());
        // Wrong-length full row is typed.
        let err = apply_z_row_diff(&mut row, &ZRowDiff::Full(vec![1, 2, 3]));
        assert!(err.is_err());
    }

    #[test]
    fn stale_epochs_are_typed() {
        assert!(require_epoch(2, 5, Some(5)).is_ok());
        let err = require_epoch(2, 5, Some(4)).unwrap_err();
        match err.downcast_ref::<MpldaError>() {
            Some(&MpldaError::StaleEpoch { position, got, have }) => {
                assert_eq!((position, got, have), (2, 5, Some(4)));
            }
            other => panic!("expected StaleEpoch, got {other:?}"),
        }
        let err = require_epoch(0, 1, None).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<MpldaError>(),
            Some(&MpldaError::StaleEpoch { have: None, .. })
        ));
    }
}
