//! Experiment drivers — one per table/figure of the paper's §5 (see
//! DESIGN.md §5 for the index). Each driver is callable from the `mplda
//! eval` CLI and from the corresponding `cargo bench` target, writes CSV
//! series via [`crate::metrics::Recorder`], and prints the rows/series the
//! paper reports.

pub mod common;
pub mod fig2;
pub mod fig3;
pub mod table1;
pub mod fig4a;
pub mod fig4b;
pub mod ablations;

pub use common::RunSummary;
