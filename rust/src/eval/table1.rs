//! E4 — Table 1: time to converge across model sizes on 64 low-end
//! machines, including the baseline's out-of-memory failures.
//!
//! Paper grid: {Wiki-unigram, Wiki-bigram} × K ∈ {5000, 10000}; Yahoo!LDA
//! completes only Wiki-unigram @ 5000 (11.8 hr vs 2.3 hr) and goes N/A
//! elsewhere because the per-node model replica exceeds 8 GiB. Here the
//! corpora are the scaled presets, K scales with them, and the per-node
//! RAM budget is scaled by the same factor so the *feasibility boundary*
//! lands in the same place: MP completes everything, YLDA only the small
//! unigram config.

use anyhow::Result;

use crate::metrics::Recorder;
use crate::util::bench::{fmt_secs, Table};
use crate::util::fmt;

use super::common::{apply_scaled_cluster, base_config, ll_threshold_common, train_summary_on, RunSummary};

#[derive(Debug, Clone)]
pub struct Opts {
    /// (corpus preset, K) grid. Paper: wiki-uni × {5000, 10000},
    /// wiki-bi × {5000, 10000}; scaled defaults keep the 1:2 K ratio.
    pub grid: Vec<(String, usize)>,
    pub iterations: usize,
    pub machines: usize,
    /// Per-node RAM budget as a fraction of the *full model* bytes — the
    /// scaled stand-in for "8 GiB vs a 200B-variable model". 0 disables
    /// the feasibility check.
    pub ram_frac_of_model: f64,
    pub out_dir: Option<String>,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            grid: vec![
                ("wiki-uni-sim".into(), 500),
                ("wiki-uni-sim".into(), 1000),
                ("wiki-bi-sim".into(), 500),
                ("wiki-bi-sim".into(), 1000),
            ],
            iterations: 10,
            machines: 64,
            ram_frac_of_model: 0.35,
            out_dir: Some("out".into()),
        }
    }
}

/// Result cell for one (corpus, K, system).
#[derive(Debug, Clone)]
pub enum Cell {
    Time(f64),
    Oom { peak: u64, budget: u64 },
    NoConverge,
}

impl Cell {
    fn render(&self) -> String {
        match self {
            Cell::Time(t) => fmt_secs(*t),
            Cell::Oom { peak, budget } => {
                format!("N/A (OOM: {} > {})", fmt::bytes(*peak), fmt::bytes(*budget))
            }
            Cell::NoConverge => "> budget*".into(),
        }
    }
}

pub fn run(opts: &Opts) -> Result<String> {
    let mut out = String::new();
    out.push_str(&format!(
        "Table 1 — time to converge, {} low-end machines (scaled corpora)\n\n",
        opts.machines
    ));
    let mut recorder = match &opts.out_dir {
        Some(d) => Recorder::with_dir(d),
        None => Recorder::new(),
    };
    let mut table = Table::new(&["corpus", "K", "model vars", "Model-Parallel", "Yahoo!LDA"]);

    for (preset, k) in &opts.grid {
        let mut cfg = base_config(preset, "low-end")?;
        cfg.cluster.machines = opts.machines;
        cfg.coord.workers = opts.machines;
        cfg.coord.blocks = 0;
        cfg.train.topics = *k;
        cfg.train.iterations = opts.iterations;
        apply_scaled_cluster(&mut cfg);
        cfg.finalize()?;
        let corpus = crate::corpus::build(&cfg.corpus)?;
        let model_vars = corpus.model_variables(*k);
        // Scaled RAM budget: fraction of the dense model bytes (4B/entry).
        let budget = if opts.ram_frac_of_model > 0.0 {
            (model_vars as f64 * 4.0 * opts.ram_frac_of_model) as u64
        } else {
            u64::MAX
        };

        log::info!("table1: {preset} K={k} ({})", corpus.summary());
        let mut mp_cfg = cfg.clone();
        mp_cfg.train.sampler = crate::config::SamplerKind::InvertedXy;
        let mp = train_summary_on(&mp_cfg, corpus.clone())?;

        let mut dp_cfg = cfg.clone();
        dp_cfg.train.sampler = crate::config::SamplerKind::SparseYao;
        let dp = train_summary_on(&dp_cfg, corpus)?;

        let th = ll_threshold_common(&mp, &dp, 0.95);
        let cell = |s: &RunSummary| -> Cell {
            if s.peak_mem_bytes > budget {
                Cell::Oom { peak: s.peak_mem_bytes, budget }
            } else {
                match s.time_to_ll(th) {
                    Some(t) => Cell::Time(t),
                    None => Cell::NoConverge,
                }
            }
        };
        let mp_cell = cell(&mp);
        let dp_cell = cell(&dp);

        let series = recorder.series(
            "table1",
            &["k", "mp_time", "dp_time", "mp_peak_mem", "dp_peak_mem", "budget"],
        );
        series.push(&[
            *k as f64,
            mp.time_to_ll(th).unwrap_or(f64::NAN),
            dp.time_to_ll(th).unwrap_or(f64::NAN),
            mp.peak_mem_bytes as f64,
            dp.peak_mem_bytes as f64,
            budget as f64,
        ]);

        table.row(&[
            preset.clone(),
            k.to_string(),
            fmt::count(model_vars),
            mp_cell.render(),
            dp_cell.render(),
        ]);
    }
    out.push_str(&table.render());
    out.push_str(
        "\n(*'> budget' = did not reach the 95% threshold within the iteration budget)\n\
         claim check: MP completes every cell; YLDA goes N/A once the replica\n\
         exceeds the scaled per-node budget (paper: V=2.5M K=10000 and all bigram cells).\n",
    );
    recorder.flush()?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_smoke() {
        let opts = Opts {
            grid: vec![("tiny".into(), 32)],
            iterations: 3,
            machines: 8,
            ram_frac_of_model: 0.0,
            out_dir: None,
        };
        let report = run(&opts).unwrap();
        assert!(report.contains("tiny"));
        assert!(report.contains("Model-Parallel"));
    }

    #[test]
    fn oom_cell_renders() {
        let c = Cell::Oom { peak: 2048, budget: 1024 };
        assert!(c.render().contains("N/A"));
    }
}
