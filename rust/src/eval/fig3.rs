//! E3 — Figure 3: the parallelization error `Δ_{r,i}` per round, with each
//! round plotted as `1/M` of an iteration. The paper's observation: the
//! error drops to ≈0 immediately and stays there — lazy `C_k` sync does not
//! degrade inference.

use anyhow::Result;

use crate::coordinator::Driver;
use crate::metrics::Recorder;
use crate::util::bench::Table;

use super::common::{apply_scaled_cluster, base_config};

#[derive(Debug, Clone)]
pub struct Opts {
    pub topics: usize,
    pub iterations: usize,
    pub workers: usize,
    pub out_dir: Option<String>,
}

impl Default for Opts {
    fn default() -> Self {
        Opts { topics: 200, iterations: 10, workers: 8, out_dir: Some("out".into()) }
    }
}

pub fn run(opts: &Opts) -> Result<String> {
    let mut cfg = base_config("pubmed-sim", "high-end")?;
    cfg.cluster.machines = opts.workers;
    cfg.coord.workers = opts.workers;
    cfg.coord.blocks = 0;
    cfg.train.topics = opts.topics;
    cfg.train.iterations = opts.iterations;
    apply_scaled_cluster(&mut cfg);
    cfg.finalize()?;

    let mut driver = Driver::new(&cfg)?;
    driver.run(opts.iterations, |_, _| {})?;

    let mut recorder = match &opts.out_dir {
        Some(d) => Recorder::with_dir(d),
        None => Recorder::new(),
    };
    let series = recorder.series("fig3_delta", &["frac_iteration", "delta"]);
    for p in driver.deltas.points() {
        series.push(&[p.frac_iteration, p.delta]);
    }
    recorder.flush()?;

    let mut out = String::new();
    out.push_str(&format!(
        "Figure 3 — Δ_r,i per round (M={} workers, K={}, pubmed-sim)\n",
        opts.workers, opts.topics
    ));
    out.push_str("Δ ∈ [0,2]; paper: 'the error is almost 0 (minimum) everywhere'\n\n");
    let mut table = Table::new(&["iteration", "mean Δ", "max Δ"]);
    for i in 0..opts.iterations {
        let pts: Vec<f64> = driver
            .deltas
            .points()
            .iter()
            .filter(|p| p.iteration == i)
            .map(|p| p.delta)
            .collect();
        let mean = pts.iter().sum::<f64>() / pts.len().max(1) as f64;
        let max = pts.iter().fold(0.0f64, |a, &b| a.max(b));
        table.row(&[format!("{i}"), format!("{mean:.3e}"), format!("{max:.3e}")]);
    }
    out.push_str(&table.render());
    out.push_str(&format!(
        "\noverall: mean Δ = {:.3e}, max Δ = {:.3e} (bound 2.0)\n",
        driver.deltas.mean_delta(),
        driver.deltas.max_delta()
    ));
    out.push_str(&format!(
        "claim check (Δ ≈ 0 everywhere): max Δ {} 0.05 → {}\n",
        if driver.deltas.max_delta() < 0.05 { "<" } else { ">=" },
        if driver.deltas.max_delta() < 0.05 { "PASS" } else { "FAIL" }
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_smoke() {
        let opts = Opts { topics: 32, iterations: 2, workers: 4, out_dir: None };
        let report = run(&opts).unwrap();
        assert!(report.contains("claim check"));
        assert!(report.contains("PASS"), "{report}");
    }
}
