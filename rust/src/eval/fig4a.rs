//! E5 — Figure 4(a): per-machine memory versus number of machines.
//!
//! The paper's result: model-parallel memory follows a `1/M` curve
//! (partitioning both data and model), while Yahoo!LDA's stays nearly flat
//! (each machine replicates most of the word–topic table).

use anyhow::Result;

use crate::metrics::Recorder;
use crate::util::bench::Table;
use crate::util::fmt;

use super::common::{apply_scaled_cluster, base_config, train_summary_on};

#[derive(Debug, Clone)]
pub struct Opts {
    pub topics: usize,
    pub machines: Vec<usize>,
    pub iterations: usize,
    pub out_dir: Option<String>,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            topics: 1000, // scaled from the paper's K=5000
            machines: vec![8, 16, 32, 64],
            iterations: 2,
            out_dir: Some("out".into()),
        }
    }
}

pub fn run(opts: &Opts) -> Result<String> {
    let mut out = String::new();
    out.push_str(&format!(
        "Figure 4(a) — per-machine peak memory vs machines (wiki-uni-sim, K={})\n\n",
        opts.topics
    ));
    let mut recorder = match &opts.out_dir {
        Some(d) => Recorder::with_dir(d),
        None => Recorder::new(),
    };
    let mut table = Table::new(&["machines", "Model-Parallel", "Yahoo!LDA", "MP ratio vs M=min"]);

    let mut mp_first = None;
    let mut rows = Vec::new();
    for &m in &opts.machines {
        let mut cfg = base_config("wiki-uni-sim", "low-end")?;
        cfg.cluster.machines = m;
        cfg.coord.workers = m;
        cfg.coord.blocks = 0;
        cfg.train.topics = opts.topics;
        cfg.train.iterations = opts.iterations;
        apply_scaled_cluster(&mut cfg);
        cfg.finalize()?;
        let corpus = crate::corpus::build(&cfg.corpus)?;

        let mut mp_cfg = cfg.clone();
        mp_cfg.train.sampler = crate::config::SamplerKind::InvertedXy;
        let mp = train_summary_on(&mp_cfg, corpus.clone())?;

        let mut dp_cfg = cfg;
        dp_cfg.train.sampler = crate::config::SamplerKind::SparseYao;
        let dp = train_summary_on(&dp_cfg, corpus)?;

        if mp_first.is_none() {
            mp_first = Some(mp.peak_mem_bytes as f64);
        }
        let ratio = mp.peak_mem_bytes as f64 / mp_first.unwrap();
        recorder.series("fig4a_memory", &["machines", "mp_bytes", "dp_bytes"]).push(&[
            m as f64,
            mp.peak_mem_bytes as f64,
            dp.peak_mem_bytes as f64,
        ]);
        rows.push((m, mp.peak_mem_bytes, dp.peak_mem_bytes, ratio));
        table.row(&[
            m.to_string(),
            fmt::bytes(mp.peak_mem_bytes),
            fmt::bytes(dp.peak_mem_bytes),
            format!("{ratio:.2}"),
        ]);
    }
    out.push_str(&table.render());

    // Claim checks: MP ~1/M; DP ~flat.
    let (m0, mp0, dp0, _) = rows[0];
    let (m1, mp1, dp1, _) = *rows.last().unwrap();
    let scale = m1 as f64 / m0 as f64;
    let mp_drop = mp0 as f64 / mp1 as f64;
    let dp_drop = dp0 as f64 / dp1 as f64;
    out.push_str(&format!(
        "\nclaim check (MP ≈ 1/M): {m0}→{m1} machines gave {mp_drop:.1}× drop \
         (ideal {scale:.0}×) → {}\n",
        if mp_drop > scale * 0.4 { "PASS" } else { "FAIL" }
    ));
    out.push_str(&format!(
        "claim check (YLDA ≈ flat): drop only {dp_drop:.2}× → {}\n",
        if dp_drop < scale * 0.4 { "PASS" } else { "FAIL" }
    ));
    recorder.flush()?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4a_smoke() {
        let opts = Opts { topics: 32, machines: vec![2, 8], iterations: 1, out_dir: None };
        let report = run(&opts).unwrap();
        assert!(report.contains("claim check"));
    }
}
