//! E6 — Figure 4(b): convergence-speedup versus number of machines on the
//! low-end (1 Gbps) cluster.
//!
//! The paper's result: model-parallel speedup tracks the ideal line, while
//! Yahoo!LDA *degrades* beyond ~16–32 machines — its all-to-server sync
//! traffic grows with M over a fixed-capacity network, so parameters go
//! stale and convergence stalls ("performs worse given 32 machines").

use anyhow::Result;

use crate::metrics::Recorder;
use crate::util::bench::{fmt_secs, Table};

use super::common::{apply_scaled_cluster, base_config, ll_threshold_common, train_summary_on, RunSummary};

#[derive(Debug, Clone)]
pub struct Opts {
    pub topics: usize,
    pub machines: Vec<usize>,
    pub iterations: usize,
    /// Threshold fraction for "time to reach LL" (paper uses a fixed LL,
    /// −2.7e9; we use frac of best final — same construct, scale-free).
    pub frac: f64,
    pub out_dir: Option<String>,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            topics: 1000, // scaled from K=5000
            machines: vec![8, 16, 32, 64],
            iterations: 12,
            frac: 0.9,
            out_dir: Some("out".into()),
        }
    }
}

pub fn run(opts: &Opts) -> Result<String> {
    let mut out = String::new();
    out.push_str(&format!(
        "Figure 4(b) — speedup vs machines (wiki-uni-sim, K={}, 1 Gbps low-end)\n\n",
        opts.topics
    ));
    let mut recorder = match &opts.out_dir {
        Some(d) => Recorder::with_dir(d),
        None => Recorder::new(),
    };

    // Collect summaries per (system, M); the threshold is fixed ONCE from
    // the smallest-M runs (the paper uses one absolute LL, −2.7e9, across
    // the whole sweep).
    let mut runs: Vec<(usize, RunSummary, RunSummary)> = Vec::new();
    for &m in &opts.machines {
        let mut cfg = base_config("wiki-uni-sim", "low-end")?;
        cfg.cluster.machines = m;
        cfg.coord.workers = m;
        cfg.coord.blocks = 0;
        cfg.train.topics = opts.topics;
        cfg.train.iterations = opts.iterations;
        apply_scaled_cluster(&mut cfg);
        cfg.finalize()?;
        let corpus = crate::corpus::build(&cfg.corpus)?;

        let mut mp_cfg = cfg.clone();
        mp_cfg.train.sampler = crate::config::SamplerKind::InvertedXy;
        let mp = train_summary_on(&mp_cfg, corpus.clone())?;

        let mut dp_cfg = cfg;
        dp_cfg.train.sampler = crate::config::SamplerKind::SparseYao;
        let dp = train_summary_on(&dp_cfg, corpus)?;

        log_summary(m, &mp, &dp);
        runs.push((m, mp, dp));
    }
    let th = ll_threshold_common(&runs[0].1, &runs[0].2, opts.frac);
    let times: Vec<(usize, Option<f64>, Option<f64>)> = runs
        .iter()
        .map(|(m, mp, dp)| (*m, mp.time_to_ll(th), dp.time_to_ll(th)))
        .collect();

    // Speedups relative to the smallest machine count.
    let (m0, mp0, dp0) = times[0].clone();
    let mut table =
        Table::new(&["machines", "MP time", "YLDA time", "MP speedup", "YLDA speedup", "ideal"]);
    for (m, mp_t, dp_t) in &times {
        let ideal = *m as f64 / m0 as f64;
        let mp_s = match (mp0, mp_t) {
            (Some(base), Some(t)) if *t > 0.0 => Some(base / t),
            _ => None,
        };
        let dp_s = match (dp0, dp_t) {
            (Some(base), Some(t)) if *t > 0.0 => Some(base / t),
            _ => None,
        };
        recorder.series("fig4b_speedup", &["machines", "mp_speedup", "dp_speedup", "ideal"]).push(
            &[
                *m as f64,
                mp_s.unwrap_or(f64::NAN),
                dp_s.unwrap_or(f64::NAN),
                ideal,
            ],
        );
        let fmt_opt = |x: &Option<f64>| x.map(fmt_secs).unwrap_or("-".into());
        let fmt_sp = |x: &Option<f64>| x.map(|s| format!("{s:.2}×")).unwrap_or("-".into());
        table.row(&[
            m.to_string(),
            fmt_opt(mp_t),
            fmt_opt(dp_t),
            fmt_sp(&mp_s),
            fmt_sp(&dp_s),
            format!("{ideal:.0}×"),
        ]);
    }
    out.push_str(&table.render());

    // Claim checks.
    let last = times.last().unwrap();
    let mp_scales = match (mp0, last.1) {
        (Some(base), Some(t)) => base / t > (last.0 as f64 / m0 as f64) * 0.4,
        _ => false,
    };
    let dp_degrades = {
        let ts = &times[..];
        {
            // YLDA's best time should NOT be at the largest M.
            let best = ts
                .iter()
                .filter_map(|(m, _, t)| t.map(|t| (*m, t)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            match best {
                Some((m_best, _)) => m_best < last.0,
                None => true,
            }
        }
    };
    out.push_str(&format!(
        "\nclaim check (MP near-ideal scaling): {}\n",
        if mp_scales { "PASS" } else { "FAIL" }
    ));
    out.push_str(&format!(
        "claim check (YLDA degrades at scale — best time not at max M): {}\n",
        if dp_degrades { "PASS" } else { "FAIL" }
    ));
    recorder.flush()?;
    Ok(out)
}

fn log_summary(m: usize, mp: &RunSummary, dp: &RunSummary) {
    log::info!(
        "fig4b M={m}: MP t={:.1}s comm={} | DP t={:.1}s comm={}",
        mp.sim_time,
        crate::util::fmt::bytes(mp.total_comm_bytes),
        dp.sim_time,
        crate::util::fmt::bytes(dp.total_comm_bytes),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4b_smoke() {
        let opts = Opts {
            topics: 32,
            machines: vec![2, 4],
            iterations: 3,
            frac: 0.8,
            out_dir: None,
        };
        let report = run(&opts).unwrap();
        assert!(report.contains("speedup"));
        assert!(report.contains("claim check"));
    }
}
