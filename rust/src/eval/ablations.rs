//! Ablations over the design choices DESIGN.md §7 calls out:
//!
//! * **block layout** — strided vs contiguous-balanced vs contiguous-even
//!   (the §Perf straggler story);
//! * **prefetch** — §3.2's comm/compute overlap on and off;
//! * **C_k sync policy** — per-round vs per-iteration (staleness/Δ trade);
//! * **blocks-per-worker** — B = M vs 2M vs 4M (rotation granularity).
//!
//! Each row reports simulated time, final LL and max Δ for the same
//! workload, so a change that "wins" on time but regresses quality is
//! visible immediately.

use anyhow::Result;

use crate::config::{BlockLayout, CkSyncPolicy, Config};
use crate::coordinator::Driver;
use crate::util::bench::{fmt_secs, Table};

#[derive(Debug, Clone)]
pub struct Opts {
    pub topics: usize,
    pub workers: usize,
    pub iterations: usize,
}

impl Default for Opts {
    fn default() -> Self {
        Opts { topics: 500, workers: 16, iterations: 5 }
    }
}

fn base(opts: &Opts) -> Result<Config> {
    let mut cfg = super::common::base_config("wiki-uni-sim", "low-end")?;
    cfg.cluster.machines = opts.workers;
    cfg.coord.workers = opts.workers;
    cfg.coord.blocks = 0;
    cfg.train.topics = opts.topics;
    cfg.train.iterations = opts.iterations;
    super::common::apply_scaled_cluster(&mut cfg);
    cfg.finalize()?;
    Ok(cfg)
}

fn run_one(cfg: &Config, corpus: &crate::corpus::Corpus) -> Result<(f64, f64, f64)> {
    let mut d = Driver::with_corpus(cfg, corpus.clone())?;
    let report = d.run(cfg.train.iterations, |_, _| {})?;
    Ok((report.sim_time, report.final_loglik, d.deltas.max_delta()))
}

pub fn run(opts: &Opts) -> Result<String> {
    let mut out = String::new();
    out.push_str(&format!(
        "Ablations — wiki-uni-sim, K={}, M={}, {} iterations\n\n",
        opts.topics, opts.workers, opts.iterations
    ));
    let cfg0 = base(opts)?;
    let corpus = crate::corpus::build(&cfg0.corpus)?;
    let mut table = Table::new(&["knob", "setting", "sim time", "final LL", "max Δ"]);

    // Block layout.
    for layout in [BlockLayout::Strided, BlockLayout::Balanced, BlockLayout::Even] {
        let mut cfg = cfg0.clone();
        cfg.coord.block_layout = layout;
        let (t, ll, d) = run_one(&cfg, &corpus)?;
        table.row(&[
            "block_layout".into(),
            layout.name().into(),
            fmt_secs(t),
            format!("{ll:.3e}"),
            format!("{d:.1e}"),
        ]);
    }

    // Prefetch.
    for prefetch in [true, false] {
        let mut cfg = cfg0.clone();
        cfg.coord.prefetch = prefetch;
        let (t, ll, d) = run_one(&cfg, &corpus)?;
        table.row(&[
            "prefetch".into(),
            prefetch.to_string(),
            fmt_secs(t),
            format!("{ll:.3e}"),
            format!("{d:.1e}"),
        ]);
    }

    // C_k sync policy.
    for policy in [CkSyncPolicy::PerRound, CkSyncPolicy::PerIteration] {
        let mut cfg = cfg0.clone();
        cfg.coord.ck_sync = policy;
        let (t, ll, d) = run_one(&cfg, &corpus)?;
        table.row(&[
            "ck_sync".into(),
            policy.name().into(),
            fmt_secs(t),
            format!("{ll:.3e}"),
            format!("{d:.1e}"),
        ]);
    }

    // Rotation granularity.
    for mult in [1usize, 2, 4] {
        let mut cfg = cfg0.clone();
        cfg.coord.blocks = opts.workers * mult;
        let (t, ll, d) = run_one(&cfg, &corpus)?;
        table.row(&[
            "blocks".into(),
            format!("{}×workers", mult),
            fmt_secs(t),
            format!("{ll:.3e}"),
            format!("{d:.1e}"),
        ]);
    }

    out.push_str(&table.render());
    out.push_str(
        "\n(expect: strided <= balanced <= even on time; prefetch faster;\n          per-iteration ck_sync larger D; finer blocks slower at this scale)\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_smoke() {
        let opts = Opts { topics: 32, workers: 4, iterations: 2 };
        let report = run(&opts).unwrap();
        assert!(report.contains("block_layout"));
        assert!(report.contains("strided"));
        assert!(report.contains("ck_sync"));
    }
}
