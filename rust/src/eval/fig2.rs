//! E1/E2 — Figure 2: convergence of model-parallel vs data-parallel
//! (Yahoo!LDA-style) inference on the Pubmed-scale corpus, high-end
//! cluster. (a) log-likelihood per iteration; (b) per simulated time.

use anyhow::Result;

use crate::metrics::Recorder;
use crate::util::bench::Table;
use crate::util::fmt;

use super::common::{apply_scaled_cluster, base_config, train_summary_on, RunSummary};

/// Experiment parameters (defaults are the scaled CI size; the paper-scale
/// values are K ∈ {1000, 5000} over the full Pubmed).
#[derive(Debug, Clone)]
pub struct Opts {
    /// Topic counts to sweep (paper: 1000, 5000).
    pub topics: Vec<usize>,
    pub iterations: usize,
    pub workers: usize,
    pub out_dir: Option<String>,
}

impl Default for Opts {
    fn default() -> Self {
        Opts { topics: vec![200, 1000], iterations: 15, workers: 8, out_dir: Some("out".into()) }
    }
}

/// Run the experiment; returns the rendered report.
pub fn run(opts: &Opts) -> Result<String> {
    let mut out = String::new();
    let mut recorder = match &opts.out_dir {
        Some(d) => Recorder::with_dir(d),
        None => Recorder::new(),
    };

    out.push_str("Figure 2 — convergence, pubmed-sim, high-end cluster\n");
    out.push_str(&format!(
        "(paper: Pubmed 8.2M docs; here: scaled pubmed-sim, {} workers)\n\n",
        opts.workers
    ));

    for &k in &opts.topics {
        let mut results: Vec<(&str, RunSummary)> = Vec::new();
        for (label, sampler) in [("model-parallel", "inverted-xy"), ("yahoo-lda", "sparse-yao")] {
            let mut cfg = base_config("pubmed-sim", "high-end")?;
            cfg.cluster.machines = opts.workers;
            cfg.coord.workers = opts.workers;
            cfg.coord.blocks = 0;
            cfg.train.topics = k;
            cfg.train.iterations = opts.iterations;
            cfg.train.sampler = crate::config::SamplerKind::parse(sampler)?;
            apply_scaled_cluster(&mut cfg);
            cfg.finalize()?;
            let corpus = crate::corpus::build(&cfg.corpus)?;
            log::info!("fig2: {label} K={k} on {}", corpus.summary());
            let summary = train_summary_on(&cfg, corpus)?;

            let series = recorder.series(
                &format!("fig2_{label}_k{k}"),
                &["iteration", "sim_time", "loglik"],
            );
            for &(i, t, ll) in &summary.ll_series {
                series.push(&[i as f64, t, ll]);
            }
            results.push((label, summary));
        }

        // Render 2(a): per-iteration.
        out.push_str(&format!("\n-- K = {k} — (a) log-likelihood per iteration --\n"));
        let mut table = Table::new(&["iter", "model-parallel", "yahoo-lda"]);
        let iters = results[0].1.ll_series.len();
        for i in 0..iters {
            table.row(&[
                format!("{}", results[0].1.ll_series[i].0),
                fmt::sci(results[0].1.ll_series[i].2),
                fmt::sci(results[1].1.ll_series.get(i).map(|x| x.2).unwrap_or(f64::NAN)),
            ]);
        }
        out.push_str(&table.render());

        // Render 2(b): per-time summary (full series in CSV).
        out.push_str(&format!("\n-- K = {k} — (b) elapsed simulated time --\n"));
        let mut table = Table::new(&["system", "final LL", "sim time", "iters to 95% of best"]);
        let th = super::common::ll_threshold(&results[0].1, &results[1].1, 0.95);
        for (label, s) in &results {
            table.row(&[
                label.to_string(),
                fmt::sci(s.final_loglik),
                crate::util::bench::fmt_secs(s.sim_time),
                s.iters_to_ll(th).map(|i| i.to_string()).unwrap_or("-".into()),
            ]);
        }
        out.push_str(&table.render());

        // The paper's claim: MP converges in fewer iterations AND less time.
        let mp_iters = results[0].1.iters_to_ll(th);
        let dp_iters = results[1].1.iters_to_ll(th);
        out.push_str(&format!(
            "claim check (MP fewer iters to threshold): MP={mp_iters:?} DP={dp_iters:?}\n"
        ));
    }

    recorder.flush()?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_smoke() {
        // Tiny version exercises the whole harness.
        let opts = Opts { topics: vec![32], iterations: 3, workers: 4, out_dir: None };
        let report = run(&opts).unwrap();
        assert!(report.contains("K = 32"));
        assert!(report.contains("model-parallel"));
        assert!(report.contains("claim check"));
    }
}
