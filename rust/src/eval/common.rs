//! Shared experiment plumbing over the [`crate::engine::Session`] facade:
//! scaled-size helpers and convergence thresholds.
//!
//! The unified runner that used to live here (`run_training`, deprecated
//! in the ISSUE 3 facade migration and removed now that every caller goes
//! through the builder) is [`crate::engine::Session`]; the figure drivers
//! go through [`train_summary_on`], a thin crate-internal wrapper that
//! adds the experiment log lines.

use anyhow::{bail, Result};

use crate::config::Config;
use crate::corpus::Corpus;
use crate::engine::SessionBuilder;

/// Unified result of a training run — the facade's summary type, re-
/// exported under its historical experiment-side name.
pub use crate::engine::TrainSummary as RunSummary;

/// Crate-internal unified runner for the figure drivers: a `Session`
/// built from `cfg`, trained with the standard experiment log lines.
///
/// * `inverted-xy` / `mh-alias` / `xla` → the model-parallel driver;
/// * `sparse-yao` / `dense` → the data-parallel Yahoo!LDA baseline
///   (dense is coerced to sparse-yao — the baseline's sampler is eq. 2).
pub(crate) fn train_summary(cfg: &Config) -> Result<RunSummary> {
    let corpus = crate::corpus::build(&cfg.corpus)?;
    train_summary_on(cfg, corpus)
}

/// See [`train_summary`]; takes a pre-built corpus.
pub(crate) fn train_summary_on(cfg: &Config, corpus: Corpus) -> Result<RunSummary> {
    let baseline = crate::sampler::caps_of(cfg.train.sampler).data_parallel_baseline;
    let mut session = SessionBuilder::from_config(cfg.clone()).corpus(corpus).build()?;
    session.train_observed(|ev| {
        if let Some(ll) = ev.loglik {
            if baseline {
                log::info!(
                    "iter {:3} t={:8.2}s ll={} skip={:.0}%",
                    ev.stats.iteration,
                    ev.stats.sim_time,
                    crate::util::fmt::sci(ll),
                    ev.skip_rate * 100.0
                );
            } else {
                log::info!(
                    "iter {:3} t={:8.2}s ll={} Δ={:.2e}",
                    ev.stats.iteration,
                    ev.stats.sim_time,
                    crate::util::fmt::sci(ll),
                    ev.stats.mean_delta
                );
            }
        }
    })
}

/// A convergence threshold for "time to converge" comparisons: the LL both
/// systems reach, set at `frac` of the way from initial to the better
/// final LL. `frac ∈ (0,1)`, paper-style thresholds use ~0.95.
pub fn ll_threshold(a: &RunSummary, b: &RunSummary, frac: f64) -> f64 {
    let init = a.ll_series.first().map(|&(_, _, ll)| ll).unwrap_or(0.0);
    let best = a.final_loglik.max(b.final_loglik);
    init + (best - init) * frac
}

/// A threshold **both** systems actually reach within their budgets: `frac`
/// of the way to the *worse* final LL. The paper's Fig 4(b)/Table 1 use a
/// fixed absolute LL both systems attain; with iteration-bounded runs the
/// min-based construct is the scale-free equivalent.
pub fn ll_threshold_common(a: &RunSummary, b: &RunSummary, frac: f64) -> f64 {
    let init = a.ll_series.first().map(|&(_, _, ll)| ll).unwrap_or(0.0);
    let worse = a.final_loglik.min(b.final_loglik);
    init + (worse - init) * frac
}

/// Calibrate the simulated cluster for a ×10⁻³-scaled corpus (DESIGN.md §4).
///
/// Two knobs restore the paper's comm:compute regime after the corpus
/// shrinks ~1000×:
///
/// * `compute_scale = 0.01` — a paper-era Opteron core samples ~20K tok/s
///   (§5); this host core does ~2M tok/s, so a simulated core at 1% of the
///   host reproduces the per-core rate the paper's timings are built on.
/// * `latency_us × 10⁻³` — per-message latency does not shrink with the
///   corpus, so an unscaled 100 µs would dominate rounds that now carry
///   1000× fewer tokens; bandwidth terms need no adjustment because block
///   and sync *bytes* already scale with the corpus.
pub fn apply_scaled_cluster(cfg: &mut Config) {
    cfg.cluster.compute_scale = 0.01;
    cfg.cluster.latency_us *= 1e-3;
}

/// Scaled experiment base config shared by the §5 harnesses.
pub fn base_config(corpus_preset: &str, cluster_preset: &str) -> Result<Config> {
    let mut cfg = Config::default();
    cfg.corpus.preset = corpus_preset.into();
    cfg.cluster.preset = cluster_preset.into();
    if corpus_preset == "wiki-bi-sim" {
        cfg.corpus.bigram = true;
    }
    cfg.train.ll_every = 1;
    cfg.finalize()?;
    Ok(cfg)
}

/// Guard rail for experiment parameter sanity.
pub fn require(cond: bool, what: &str) -> Result<()> {
    if !cond {
        bail!("experiment parameter error: {what}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(sampler: &str) -> Config {
        let mut cfg = Config::from_str(&format!(
            "[corpus]\npreset = \"tiny\"\n[train]\ntopics = 16\niterations = 3\nsampler = \"{sampler}\"\n[coord]\nworkers = 4\n[cluster]\npreset = \"custom\"\nmachines = 4"
        ))
        .unwrap();
        cfg.finalize().unwrap();
        cfg
    }

    #[test]
    fn unified_runner_both_systems() {
        let mp = train_summary(&quick_cfg("inverted-xy")).unwrap();
        let dp = train_summary(&quick_cfg("sparse-yao")).unwrap();
        assert!(mp.final_loglik.is_finite() && dp.final_loglik.is_finite());
        assert!(mp.total_tokens > 0 && dp.total_tokens > 0);
        assert_eq!(mp.ll_series.len(), 4); // init + 3 iters
        assert!(mp.mean_delta >= 0.0);
    }

    #[test]
    fn time_to_ll_interpolates() {
        let s = RunSummary {
            ll_series: vec![(0, 0.0, -100.0), (1, 10.0, -50.0), (2, 20.0, -10.0)],
            ..Default::default()
        };
        let t = s.time_to_ll(-30.0).unwrap();
        assert!(t > 10.0 && t < 20.0);
        assert!(s.time_to_ll(0.0).is_none());
        assert_eq!(s.iters_to_ll(-50.0), Some(1));
    }

    #[test]
    fn threshold_between_init_and_best() {
        let a = RunSummary {
            ll_series: vec![(0, 0.0, -100.0)],
            final_loglik: -20.0,
            ..Default::default()
        };
        let b = RunSummary { final_loglik: -30.0, ..a.clone() };
        let th = ll_threshold(&a, &b, 0.9);
        assert!(th > -100.0 && th < -20.0);
    }
}
