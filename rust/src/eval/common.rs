//! Shared experiment plumbing: unified training entry point over both
//! systems (model-parallel driver and the Yahoo!LDA baseline), scaled-size
//! helpers, and report rendering.

use anyhow::{bail, Result};

use crate::baseline::YahooLda;
use crate::config::{Config, SamplerKind};
use crate::coordinator::Driver;
use crate::corpus::Corpus;
use crate::runtime::XlaExecutor;

/// Unified result of a training run (either system).
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    /// (iteration, sim_time_secs, loglik) checkpoints; entry 0 is init.
    pub ll_series: Vec<(usize, f64, f64)>,
    pub final_loglik: f64,
    pub sim_time: f64,
    pub peak_mem_bytes: u64,
    pub total_comm_bytes: u64,
    pub total_tokens: u64,
    /// Mean Δ_{r,i} (MP runs only; 0 for the baseline).
    pub mean_delta: f64,
    pub max_delta: f64,
    /// Host compute seconds actually burned (for throughput reporting).
    pub host_compute_secs: f64,
}

impl RunSummary {
    /// Simulated time at which the LL series first reaches `threshold`
    /// (linear interpolation), if it does.
    pub fn time_to_ll(&self, threshold: f64) -> Option<f64> {
        let mut prev: Option<(f64, f64)> = None;
        for &(_, t, ll) in &self.ll_series {
            if ll >= threshold {
                return Some(match prev {
                    Some((pt, pll)) if ll > pll => pt + (t - pt) * (threshold - pll) / (ll - pll),
                    _ => t,
                });
            }
            prev = Some((t, ll));
        }
        None
    }

    /// Iterations to reach `threshold`.
    pub fn iters_to_ll(&self, threshold: f64) -> Option<usize> {
        self.ll_series.iter().find(|&&(_, _, ll)| ll >= threshold).map(|&(i, _, _)| i)
    }
}

/// Train per `cfg` and return the unified summary.
///
/// * `inverted-xy` / `xla` → the model-parallel [`Driver`];
/// * `sparse-yao` / `dense` → the data-parallel [`YahooLda`] baseline
///   (dense is coerced to sparse-yao — the baseline's sampler is eq. 2).
pub fn run_training(cfg: &Config) -> Result<RunSummary> {
    let corpus = crate::corpus::build(&cfg.corpus)?;
    run_training_on(cfg, corpus)
}

/// Same, over a pre-built corpus (experiments reuse corpora).
pub fn run_training_on(cfg: &Config, corpus: Corpus) -> Result<RunSummary> {
    match cfg.train.sampler {
        SamplerKind::InvertedXy | SamplerKind::Xla => {
            let mut driver = Driver::with_corpus(cfg, corpus)?;
            if cfg.train.sampler == SamplerKind::Xla {
                let exec = XlaExecutor::from_dir(
                    &cfg.runtime.artifacts_dir,
                    &driver.params,
                    cfg.train.microbatch,
                )?;
                driver.set_executor(Box::new(exec));
            }
            let report = driver.run(cfg.train.iterations, |stats, ll| {
                if let Some(ll) = ll {
                    log::info!(
                        "iter {:3} t={:8.2}s ll={} Δ={:.2e}",
                        stats.iteration,
                        stats.sim_time,
                        crate::util::fmt::sci(ll),
                        stats.mean_delta
                    );
                }
            })?;
            let host = report.iters.iter().map(|i| i.host_compute_secs).sum();
            Ok(RunSummary {
                ll_series: report.ll_series,
                final_loglik: report.final_loglik,
                sim_time: report.sim_time,
                peak_mem_bytes: report.peak_mem_bytes,
                total_comm_bytes: report.total_comm_bytes,
                total_tokens: report.total_tokens,
                mean_delta: driver.deltas.mean_delta(),
                max_delta: driver.deltas.max_delta(),
                host_compute_secs: host,
            })
        }
        SamplerKind::SparseYao | SamplerKind::Dense => {
            let mut y = YahooLda::with_corpus(cfg, corpus)?;
            let report = y.run(cfg.train.iterations, |stats, ll| {
                if let Some(ll) = ll {
                    log::info!(
                        "iter {:3} t={:8.2}s ll={} skip={:.0}%",
                        stats.iteration,
                        stats.sim_time,
                        crate::util::fmt::sci(ll),
                        stats.skip_rate * 100.0
                    );
                }
            })?;
            let host = report.iters.iter().map(|i| i.host_compute_secs).sum();
            Ok(RunSummary {
                ll_series: report.ll_series,
                final_loglik: report.final_loglik,
                sim_time: report.sim_time,
                peak_mem_bytes: report.peak_mem_bytes,
                total_comm_bytes: report.total_comm_bytes,
                total_tokens: report.total_tokens,
                mean_delta: 0.0,
                max_delta: 0.0,
                host_compute_secs: host,
            })
        }
    }
}

/// A convergence threshold for "time to converge" comparisons: the LL both
/// systems reach, set at `frac` of the way from initial to the better
/// final LL. `frac ∈ (0,1)`, paper-style thresholds use ~0.95.
pub fn ll_threshold(a: &RunSummary, b: &RunSummary, frac: f64) -> f64 {
    let init = a.ll_series.first().map(|&(_, _, ll)| ll).unwrap_or(0.0);
    let best = a.final_loglik.max(b.final_loglik);
    init + (best - init) * frac
}

/// A threshold **both** systems actually reach within their budgets: `frac`
/// of the way to the *worse* final LL. The paper's Fig 4(b)/Table 1 use a
/// fixed absolute LL both systems attain; with iteration-bounded runs the
/// min-based construct is the scale-free equivalent.
pub fn ll_threshold_common(a: &RunSummary, b: &RunSummary, frac: f64) -> f64 {
    let init = a.ll_series.first().map(|&(_, _, ll)| ll).unwrap_or(0.0);
    let worse = a.final_loglik.min(b.final_loglik);
    init + (worse - init) * frac
}

/// Calibrate the simulated cluster for a ×10⁻³-scaled corpus (DESIGN.md §4).
///
/// Two knobs restore the paper's comm:compute regime after the corpus
/// shrinks ~1000×:
///
/// * `compute_scale = 0.01` — a paper-era Opteron core samples ~20K tok/s
///   (§5); this host core does ~2M tok/s, so a simulated core at 1% of the
///   host reproduces the per-core rate the paper's timings are built on.
/// * `latency_us × 10⁻³` — per-message latency does not shrink with the
///   corpus, so an unscaled 100 µs would dominate rounds that now carry
///   1000× fewer tokens; bandwidth terms need no adjustment because block
///   and sync *bytes* already scale with the corpus.
pub fn apply_scaled_cluster(cfg: &mut Config) {
    cfg.cluster.compute_scale = 0.01;
    cfg.cluster.latency_us *= 1e-3;
}

/// Scaled experiment base config shared by the §5 harnesses.
pub fn base_config(corpus_preset: &str, cluster_preset: &str) -> Result<Config> {
    let mut cfg = Config::default();
    cfg.corpus.preset = corpus_preset.into();
    cfg.cluster.preset = cluster_preset.into();
    if corpus_preset == "wiki-bi-sim" {
        cfg.corpus.bigram = true;
    }
    cfg.train.ll_every = 1;
    cfg.finalize()?;
    Ok(cfg)
}

/// Guard rail for experiment parameter sanity.
pub fn require(cond: bool, what: &str) -> Result<()> {
    if !cond {
        bail!("experiment parameter error: {what}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(sampler: &str) -> Config {
        let mut cfg = Config::from_str(&format!(
            "[corpus]\npreset = \"tiny\"\n[train]\ntopics = 16\niterations = 3\nsampler = \"{sampler}\"\n[coord]\nworkers = 4\n[cluster]\npreset = \"custom\"\nmachines = 4"
        ))
        .unwrap();
        cfg.finalize().unwrap();
        cfg
    }

    #[test]
    fn unified_runner_both_systems() {
        let mp = run_training(&quick_cfg("inverted-xy")).unwrap();
        let dp = run_training(&quick_cfg("sparse-yao")).unwrap();
        assert!(mp.final_loglik.is_finite() && dp.final_loglik.is_finite());
        assert!(mp.total_tokens > 0 && dp.total_tokens > 0);
        assert_eq!(mp.ll_series.len(), 4); // init + 3 iters
        assert!(mp.mean_delta >= 0.0);
    }

    #[test]
    fn time_to_ll_interpolates() {
        let s = RunSummary {
            ll_series: vec![(0, 0.0, -100.0), (1, 10.0, -50.0), (2, 20.0, -10.0)],
            ..Default::default()
        };
        let t = s.time_to_ll(-30.0).unwrap();
        assert!(t > 10.0 && t < 20.0);
        assert!(s.time_to_ll(0.0).is_none());
        assert_eq!(s.iters_to_ll(-50.0), Some(1));
    }

    #[test]
    fn threshold_between_init_and_best() {
        let a = RunSummary {
            ll_series: vec![(0, 0.0, -100.0)],
            final_loglik: -20.0,
            ..Default::default()
        };
        let b = RunSummary { final_loglik: -30.0, ..a.clone() };
        let th = ll_threshold(&a, &b, 0.9);
        assert!(th > -100.0 && th < -20.0);
    }
}
