//! `ShardedTopicModel` — fold-in inference against a model that **stays
//! block-sharded** in the [`KvStore`].
//!
//! [`Session::freeze`](crate::engine::Session::freeze) materializes the
//! whole word–topic table densely, which caps servable model size at one
//! node's RAM — exactly the limit the paper's block sharding exists to
//! break. This type is the serving-side answer: the trained blocks stay
//! in the store, and queries page them on demand through an **LRU cache**
//! bounded by `serve.cache_budget_mib`:
//!
//! * Block reads are **read-only concurrent leases**
//!   ([`KvStore::read_block`]) — the store stays intact and any number of
//!   queries page in parallel.
//! * The cache **never admits past its budget**: admission evicts
//!   least-recently-used blocks first, and a block larger than the whole
//!   budget is served *uncached* (a bypass). `MemCategory::ServeCache`
//!   under the standard [`MemoryAccountant`] witnesses the bound — its
//!   peak can never exceed the budget.
//! * Each request's working set is **pinned** for the request's
//!   duration: the fallible pre-pass returns the `Arc`s it paged, row
//!   visits answer from that pinned set, and the sampling path performs
//!   no store reads at all — so a store fault can only fail the pre-pass
//!   (a typed request error), never panic mid-batch, even when the
//!   working set exceeds the budget and the cache evicts it.
//! * A model larger than the cache therefore still serves **correctly,
//!   just slower** — and bitwise identically: the fold-in arithmetic is
//!   the same `engine::infer` fold-in core the offline
//!   [`TopicModel`](crate::engine::TopicModel) runs, and cache state can
//!   only change *when* a row is fetched, never *what* it contains
//!   (`tests/serve_determinism.rs`).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::metrics::LatencyHistogram;
use crate::cluster::{ClusterSpec, MemCategory, MemoryAccountant};
use crate::config::ClusterConfig;
use crate::engine::infer::{infer_batch, infer_batch_reusing, FrozenStats, RowSource};
use crate::engine::{BowDoc, DocTopics, InferOptions};
use crate::kvstore::{KvStore, ShardMap, TransferKind};
use crate::model::{Assignments, BlockMap, ModelBlock, SparseRow, TopicCounts, WordTopicTable};
use crate::sampler::{Params, Scratch};

/// Block-cache counters, snapshotted by [`ShardedTopicModel::cache_stats`].
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    /// Row lookups answered from the cache.
    pub hits: u64,
    /// Lookups that paged a block in from the store.
    pub misses: u64,
    /// Lookups whose block exceeded the whole budget and was served
    /// uncached (counts as a miss for hit-rate purposes).
    pub bypasses: u64,
    /// Blocks evicted to make room.
    pub evictions: u64,
    /// Blocks resident right now.
    pub resident_blocks: usize,
    /// Bytes resident right now.
    pub resident_bytes: u64,
    /// Peak resident bytes ever (the `ServeCache` accountant category —
    /// must never exceed `budget_bytes` when a budget is set).
    pub peak_bytes: u64,
    /// The configured budget in bytes (0 = unlimited).
    pub budget_bytes: u64,
}

impl CacheStats {
    /// Fraction of block lookups answered without touching the store.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses + self.bypasses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Disk-tier counters, snapshotted by [`ShardedTopicModel::disk_stats`]:
/// the out-of-core block store's spill/recall traffic
/// ([`crate::storage`]) as seen from the serving tier, plus the recall
/// latency distribution this process actually paid. All zeros when the
/// store has no disk tier attached.
#[derive(Debug, Clone, Copy, Default)]
pub struct DiskStats {
    /// Whether an out-of-core tier is attached to the backing store.
    pub attached: bool,
    /// Blocks recalled (decoded back) from disk segments.
    pub recalls: u64,
    /// Segment bytes read back by recalls.
    pub recall_bytes: u64,
    /// Segment bytes appended by spills.
    pub spill_bytes: u64,
    /// 99th-percentile recall latency in milliseconds (log₂-bucket upper
    /// bound; 0 with no samples).
    pub recall_p99_ms: f64,
}

struct CacheEntry {
    block: Arc<ModelBlock>,
    bytes: u64,
    last_used: u64,
}

/// The LRU block cache plus its accounting, all behind one mutex so
/// budget checks, admission and counters stay coherent.
struct BlockCache {
    entries: BTreeMap<u32, CacheEntry>,
    /// Monotone access clock for LRU ordering.
    tick: u64,
    /// Bytes currently resident.
    bytes: u64,
    /// Admission budget in bytes; 0 = unlimited.
    budget: u64,
    /// Single-node accountant charged under `MemCategory::ServeCache`.
    mem: MemoryAccountant,
    hits: u64,
    misses: u64,
    bypasses: u64,
    evictions: u64,
}

/// A trained LDA model served straight from its block shards.
pub struct ShardedTopicModel {
    kv: KvStore,
    map: BlockMap,
    stats: FrozenStats,
    num_words: usize,
    cache: Mutex<BlockCache>,
    /// Wall-clock latency of cache misses that hit a **spilled** block —
    /// the price of serving straight from an out-of-core store
    /// ([`ShardedTopicModel::disk_stats`]). Separate from the cache lock:
    /// recalls are timed with that lock released.
    recall_hist: Mutex<LatencyHistogram>,
}

/// One request's working set, pinned for the request's whole duration:
/// every block its documents touch, held by `Arc` from the fallible
/// [`ShardedTopicModel::pin`] pre-pass. Row visits answer from this set
/// and never go back to the store or the cache — so later LRU evictions
/// (a working set larger than the budget evicts its own pre-passed
/// blocks), over-budget bypasses, and store faults injected mid-request
/// cannot reach the sampling path. The only fallible store reads happen
/// in the pre-pass, where they fail the request with a typed error.
struct PinnedBlocks<'a> {
    map: &'a BlockMap,
    num_words: usize,
    blocks: BTreeMap<u32, Arc<ModelBlock>>,
}

impl RowSource for PinnedBlocks<'_> {
    fn with_row(&self, w: u32, f: &mut dyn FnMut(&SparseRow)) {
        let block = self
            .blocks
            .get(&(self.map.block_of(w) as u32))
            // Unreachable via store state: the pre-pass pinned the block
            // of every in-vocabulary word in the request's documents, and
            // out-of-vocabulary words are rejected before sampling.
            .expect("word outside the request's pinned working set");
        f(block.row(w));
    }

    fn num_words(&self) -> usize {
        self.num_words
    }
}

impl ShardedTopicModel {
    /// Package a quiescent block store for serving. Fails if any block is
    /// still leased (training in flight), the layout does not cover the
    /// vocabulary, or the totals are invalid — a model that constructs is
    /// servable.
    pub fn new(
        kv: KvStore,
        map: BlockMap,
        params: Params,
        num_words: usize,
        cache_budget_mib: f64,
    ) -> Result<ShardedTopicModel> {
        if kv.num_leased() != 0 {
            bail!(
                "store not quiescent: {} blocks still leased — finish training before serving",
                kv.num_leased()
            );
        }
        if !map.is_exact_cover(num_words) {
            bail!("block layout does not cover the vocabulary (V={num_words})");
        }
        if cache_budget_mib < 0.0 {
            bail!("serve cache budget must be >= 0 (0 = unlimited)");
        }
        let stats = FrozenStats::new(&kv.totals_snapshot(), params)?;
        let budget = (cache_budget_mib * (1u64 << 20) as f64).round() as u64;
        let capacity = if budget > 0 { budget } else { u64::MAX };
        let cache = BlockCache {
            entries: BTreeMap::new(),
            tick: 0,
            bytes: 0,
            budget,
            mem: MemoryAccountant::new(1, capacity, false),
            hits: 0,
            misses: 0,
            bypasses: 0,
            evictions: 0,
        };
        Ok(ShardedTopicModel {
            kv,
            map,
            stats,
            num_words,
            cache: Mutex::new(cache),
            recall_hist: Mutex::new(LatencyHistogram::new()),
        })
    }

    /// Build a sharded serving model from a dense table (tests and
    /// benches compare paged serving against the offline model this way):
    /// the table is cut into `num_blocks` strided blocks homed on one
    /// simulated machine.
    pub fn from_table(
        wt: &WordTopicTable,
        ck: TopicCounts,
        params: Params,
        num_blocks: usize,
        cache_budget_mib: f64,
    ) -> Result<ShardedTopicModel> {
        if num_blocks == 0 || num_blocks > wt.num_words() {
            bail!(
                "need 1 <= blocks <= V, got {num_blocks} blocks over V={}",
                wt.num_words()
            );
        }
        let map = BlockMap::strided(wt.num_words(), num_blocks);
        let blocks = Assignments::build_blocks(wt, &map);
        let spec = ClusterSpec::from_config(&ClusterConfig {
            machines: 1,
            ..ClusterConfig::default()
        });
        let shards = ShardMap::round_robin(num_blocks, &spec);
        let kv = KvStore::new(blocks, ck, shards);
        Self::new(kv, map, params, wt.num_words(), cache_budget_mib)
    }

    /// Number of topics `K`.
    pub fn num_topics(&self) -> usize {
        self.stats.params.num_topics
    }

    /// Vocabulary size `V`.
    pub fn num_words(&self) -> usize {
        self.num_words
    }

    /// Number of model blocks backing the store.
    pub fn num_blocks(&self) -> usize {
        self.map.num_blocks()
    }

    /// The hyperparameters the model was trained with.
    pub fn params(&self) -> &Params {
        &self.stats.params
    }

    /// Which block owns word `w`'s row.
    pub fn block_of(&self, w: u32) -> u32 {
        self.map.block_of(w) as u32
    }

    /// Total bytes of all blocks in the store (for sizing cache budgets
    /// relative to the model: "full" = this, "starved" = about one
    /// block).
    pub fn total_block_bytes(&self) -> u64 {
        self.kv.with_resident_blocks(|blocks| blocks.map(|b| b.bytes()).sum())
    }

    /// Bytes of the largest single block (the smallest budget that still
    /// caches at all).
    pub fn max_block_bytes(&self) -> u64 {
        self.kv.with_resident_blocks(|blocks| blocks.map(|b| b.bytes()).max().unwrap_or(0))
    }

    /// Get block `id`, from cache or by paging it in. The returned `Arc`
    /// stays valid across evictions, so row visits never hold the cache
    /// lock — and neither does the O(block) store copy on a miss: the
    /// lock covers only the map lookups and the admission bookkeeping,
    /// so concurrent queries keep hitting unrelated blocks while one
    /// pages in. (Two threads missing the *same* block may both pay the
    /// copy; admission below dedupes, and both copies are equal.)
    ///
    /// A failed store read (e.g. an injected
    /// [`crate::error::MpldaError::ReadFault`]) propagates — cache state
    /// is untouched, so the next attempt retries the store cleanly.
    fn block(&self, id: u32) -> Result<Arc<ModelBlock>> {
        {
            let mut cache = self.cache.lock().expect("serve cache lock poisoned");
            cache.tick += 1;
            let tick = cache.tick;
            if let Some(e) = cache.entries.get_mut(&id) {
                e.last_used = tick;
                let block = e.block.clone();
                cache.hits += 1;
                return Ok(block);
            }
        }
        // Page in with the lock released. A spilled block pays a disk
        // recall inside the store read — time it so `disk_stats` can
        // report the latency distribution of serving out-of-core.
        let spilled = self.kv.is_spilled(id);
        let started = Instant::now();
        let block = self.kv.read_block(id, 0)?;
        if spilled {
            let micros = started.elapsed().as_micros().min(u64::MAX as u128) as u64;
            self.recall_hist.lock().expect("recall histogram lock poisoned").record(micros);
        }
        let bytes = block.bytes();
        let arc = Arc::new(block);
        let mut cache = self.cache.lock().expect("serve cache lock poisoned");
        let tick = cache.tick;
        if let Some(e) = cache.entries.get_mut(&id) {
            // A racing misser admitted it while we copied. Serve the
            // cached one (LRU stays coherent); our fetch still counts —
            // it really hit the store.
            e.last_used = tick;
            let block = e.block.clone();
            cache.misses += 1;
            return Ok(block);
        }
        if cache.budget > 0 && bytes > cache.budget {
            // Larger than the whole budget: serve uncached. The budget
            // is a hard admission bound, never exceeded.
            cache.bypasses += 1;
            return Ok(arc);
        }
        cache.misses += 1;
        while cache.budget > 0 && cache.bytes + bytes > cache.budget {
            // Evict least-recently-used until the newcomer fits. The loop
            // terminates: either entries shrink to empty (then
            // cache.bytes == 0 and the bypass check above guarantees
            // bytes <= budget) or the condition clears first.
            let victim = cache
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&vid, _)| vid)
                .expect("eviction loop ran with an empty cache");
            let evicted = cache.entries.remove(&victim).expect("victim came from the map");
            cache.bytes -= evicted.bytes;
            cache.mem.release(0, MemCategory::ServeCache, evicted.bytes);
            cache.evictions += 1;
        }
        cache.bytes += bytes;
        cache
            .mem
            .charge(0, MemCategory::ServeCache, bytes)
            .expect("serve cache accountant does not enforce");
        cache.entries.insert(id, CacheEntry { block: arc.clone(), bytes, last_used: tick });
        Ok(arc)
    }

    /// Fallibly page in and **pin** every block `docs` will touch — the
    /// pre-pass each fold-in entry point runs. A store fault fails the
    /// *request* with a typed error before any sampling work starts, and
    /// the returned [`PinnedBlocks`] keeps the working set alive for the
    /// request even if the cache evicts (or never admitted) some of it —
    /// the sampling path performs no store reads at all.
    fn pin(&self, docs: &[BowDoc]) -> Result<PinnedBlocks<'_>> {
        let mut blocks = BTreeMap::new();
        for id in self.blocks_of(docs) {
            let block =
                self.block(id).with_context(|| format!("paging block {id} for fold-in"))?;
            blocks.insert(id, block);
        }
        Ok(PinnedBlocks { map: &self.map, num_words: self.num_words, blocks })
    }

    /// The backing block store — the serve fault-injection tests reach
    /// [`KvStore::inject_read_fault`] through this.
    pub fn store(&self) -> &KvStore {
        &self.kv
    }

    /// Warm the cache with each listed block once, in the given order —
    /// the micro-batcher's group-by-block pre-pass, which amortizes one
    /// store read across every queued document that touches the block.
    /// Out-of-range ids are ignored (per-document validation reports them
    /// properly later), and so are store faults — warming is best-effort;
    /// the request's own pre-pass surfaces any error as a typed failure.
    pub fn touch_blocks(&self, ids: &[u32]) {
        for &id in ids {
            if (id as usize) < self.map.num_blocks() {
                let _ = self.block(id);
            }
        }
    }

    /// The distinct blocks a set of documents will touch, ascending —
    /// what the batcher feeds [`ShardedTopicModel::touch_blocks`]. Takes
    /// any document iterator so the executor can sweep a whole batch of
    /// requests without concatenating them. Out-of-vocabulary words are
    /// skipped here (per-document validation reports them properly).
    pub fn blocks_of<'a, I: IntoIterator<Item = &'a BowDoc>>(&self, docs: I) -> Vec<u32> {
        let mut ids: Vec<u32> = docs
            .into_iter()
            .flat_map(|d| d.tokens.iter())
            .filter(|&&w| (w as usize) < self.num_words)
            .map(|&w| self.map.block_of(w) as u32)
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Snapshot the cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        let cache = self.cache.lock().expect("serve cache lock poisoned");
        CacheStats {
            hits: cache.hits,
            misses: cache.misses,
            bypasses: cache.bypasses,
            evictions: cache.evictions,
            resident_blocks: cache.entries.len(),
            resident_bytes: cache.bytes,
            peak_bytes: cache.mem.peak_category(0, MemCategory::ServeCache),
            budget_bytes: cache.budget,
        }
    }

    /// Snapshot the disk-tier counters: the backing store's lifetime
    /// spill/recall byte totals plus the recall latency distribution this
    /// serving process paid on cache misses of spilled blocks. All zeros
    /// when no out-of-core tier is attached.
    pub fn disk_stats(&self) -> DiskStats {
        let hist = self.recall_hist.lock().expect("recall histogram lock poisoned");
        DiskStats {
            attached: self.kv.storage_attached(),
            recalls: self.kv.count_of(TransferKind::BlockRecall),
            recall_bytes: self.kv.bytes_of(TransferKind::BlockRecall),
            spill_bytes: self.kv.bytes_of(TransferKind::BlockSpill),
            recall_p99_ms: hist.percentile_ms(99.0),
        }
    }

    /// A copy of the disk-recall latency histogram (Prometheus
    /// exposition renders the whole distribution; [`DiskStats`] carries
    /// only its p99).
    pub fn recall_histogram(&self) -> LatencyHistogram {
        self.recall_hist.lock().expect("recall histogram lock poisoned").clone()
    }

    /// Fold in a batch with default options — same contract as
    /// [`TopicModel::infer`](crate::engine::TopicModel::infer), bitwise
    /// identical results.
    pub fn infer(&self, docs: &[BowDoc]) -> Result<DocTopics> {
        self.infer_with(docs, &InferOptions::default())
    }

    /// Fold in a batch of held-out documents. Bitwise identical to
    /// [`TopicModel::infer_with`](crate::engine::TopicModel::infer_with)
    /// over the same trained state, for every cache budget and thread
    /// count: per-document RNG streams are keyed by batch position, and
    /// paging changes only when rows are fetched, never their contents.
    pub fn infer_with(&self, docs: &[BowDoc], opts: &InferOptions) -> Result<DocTopics> {
        let pinned = self.pin(docs)?;
        infer_batch(&self.stats, &pinned, docs, opts)
    }

    /// [`ShardedTopicModel::infer_with`] reusing caller-held scratches
    /// (one worker thread per scratch; `opts.threads` is ignored).
    pub fn infer_with_scratch(
        &self,
        docs: &[BowDoc],
        opts: &InferOptions,
        scratches: &mut [Scratch],
    ) -> Result<DocTopics> {
        let pinned = self.pin(docs)?;
        infer_batch_reusing(&self.stats, &pinned, docs, opts.iterations, opts.seed, scratches)
    }

    /// Serve one *request*: fold in its documents on RNG streams keyed by
    /// position **within the request** — the same streams the offline
    /// model would use for the request as a standalone batch — so results
    /// are independent of how the micro-batcher groups requests, of batch
    /// size, and of server thread count.
    pub fn fold_in_request(
        &self,
        docs: &[BowDoc],
        seed: u64,
        iterations: usize,
        scratch: &mut Scratch,
    ) -> Result<DocTopics> {
        let pinned = self.pin(docs)?;
        infer_batch_reusing(
            &self.stats,
            &pinned,
            docs,
            iterations,
            seed,
            std::slice::from_mut(scratch),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// A small synthetic trained state with non-trivial rows.
    fn table(v: usize, k: usize, seed: u64) -> (WordTopicTable, TopicCounts, Params) {
        let mut rng = Pcg64::new(seed);
        let mut wt = WordTopicTable::zeros(v, k);
        let mut ck = TopicCounts::zeros(k);
        for w in 0..v {
            for _ in 0..rng.next_below(6) {
                let t = rng.next_below(k as u64) as u32;
                wt.row_mut(w).inc(t);
                ck.inc(t as usize);
            }
        }
        (wt, ck, Params::new(k, v, 0.1, 0.01))
    }

    fn docs(v: usize, n: usize, len: usize, seed: u64) -> Vec<BowDoc> {
        let mut rng = Pcg64::new(seed);
        (0..n)
            .map(|_| BowDoc::new((0..len).map(|_| rng.next_below(v as u64) as u32).collect()))
            .collect()
    }

    #[test]
    fn pages_blocks_and_answers_rows() {
        let (wt, ck, params) = table(60, 8, 3);
        let m = ShardedTopicModel::from_table(&wt, ck, params, 6, 0.0).unwrap();
        assert_eq!(m.num_blocks(), 6);
        assert_eq!(m.num_words(), 60);
        // Every word's row matches the dense table through the pinned view.
        let all = BowDoc::new((0..60).collect());
        let pinned = m.pin(std::slice::from_ref(&all)).unwrap();
        for w in 0..60u32 {
            pinned.with_row(w, &mut |row| assert_eq!(row, wt.row(w as usize), "word {w}"));
        }
        let s = m.cache_stats();
        assert_eq!(s.misses, 6, "each block paged once");
        assert_eq!(s.hits, 0, "row visits never touch the cache");
        assert_eq!(s.resident_blocks, 6);
        assert_eq!(s.evictions, 0);
        // A second pin of the same working set runs hit-only.
        m.pin(std::slice::from_ref(&all)).unwrap();
        let s = m.cache_stats();
        assert_eq!(s.misses, 6);
        assert_eq!(s.hits, 6);
        assert!(s.hit_rate() >= 0.5);
    }

    #[test]
    fn working_set_stays_pinned_across_its_own_evictions() {
        // Budget fits ~2 of 8 blocks while one request touches all 8: the
        // pre-pass evicts its own earlier pins as it pages. The pinned
        // `Arc`s must keep answering row visits — the sampling path never
        // goes back to the store, so the request's store-read count is
        // exactly the block count (pre-pass only).
        let (wt, ck, params) = table(120, 8, 4);
        let full = ShardedTopicModel::from_table(&wt, ck.clone(), params, 8, 0.0).unwrap();
        let per_block = full.max_block_bytes();
        let budget_mib = (per_block * 2) as f64 / (1u64 << 20) as f64;
        let m = ShardedTopicModel::from_table(&wt, ck, params, 8, budget_mib).unwrap();
        let qs = docs(120, 10, 60, 21);
        let wanted = m.blocks_of(&qs).len() as u64;
        assert_eq!(wanted, 8, "the request must touch every block");
        let folded = m.infer(&qs).unwrap();
        assert_eq!(folded.len(), 10);
        let s = m.cache_stats();
        assert!(s.evictions > 0, "the pre-pass must evict under this budget");
        assert_eq!(
            s.misses + s.bypasses,
            wanted,
            "row visits must be answered by the pinned set, not fresh store reads"
        );
        assert!(
            s.peak_bytes <= s.budget_bytes,
            "ServeCache peak {} exceeded budget {}",
            s.peak_bytes,
            s.budget_bytes
        );
    }

    #[test]
    fn budget_is_a_hard_admission_bound() {
        let (wt, ck, params) = table(120, 8, 4);
        let full = ShardedTopicModel::from_table(&wt, ck.clone(), params, 8, 0.0).unwrap();
        let per_block = full.max_block_bytes();
        // Budget fits roughly two blocks: constant eviction, never over.
        let budget_mib = (per_block * 2) as f64 / (1u64 << 20) as f64;
        let m = ShardedTopicModel::from_table(&wt, ck.clone(), params, 8, budget_mib).unwrap();
        let qs = docs(120, 10, 40, 9);
        let folded = m.infer(&qs).unwrap();
        assert_eq!(folded.len(), 10);
        let s = m.cache_stats();
        assert!(s.evictions > 0, "a starved cache must evict");
        assert!(s.budget_bytes > 0);
        assert!(
            s.peak_bytes <= s.budget_bytes,
            "ServeCache peak {} exceeded budget {}",
            s.peak_bytes,
            s.budget_bytes
        );
        // Tiny budget (smaller than any block): everything bypasses,
        // nothing is ever admitted — and serving still works.
        let tiny = ShardedTopicModel::from_table(&wt, ck, params, 8, 1e-6).unwrap();
        tiny.infer(&qs).unwrap();
        let ts = tiny.cache_stats();
        assert_eq!(ts.misses, 0);
        assert!(ts.bypasses > 0);
        assert_eq!(ts.peak_bytes, 0);
        assert_eq!(ts.resident_blocks, 0);
    }

    #[test]
    fn served_results_equal_offline_at_every_budget() {
        let (wt, ck, params) = table(100, 12, 5);
        let offline = crate::engine::TopicModel::new(wt.clone(), ck.clone(), params).unwrap();
        let qs = docs(100, 12, 30, 11);
        let opts = InferOptions { iterations: 8, seed: 99, threads: 3 };
        let reference = offline.infer_with(&qs, &opts).unwrap();
        let snap = |dt: &DocTopics| -> Vec<Vec<(u32, u32)>> {
            (0..dt.len()).map(|d| dt.counts(d).iter().collect()).collect()
        };
        for budget_mib in [0.0, 0.001, 0.005] {
            let m =
                ShardedTopicModel::from_table(&wt, ck.clone(), params, 10, budget_mib).unwrap();
            let served = m.infer_with(&qs, &opts).unwrap();
            assert_eq!(
                snap(&reference),
                snap(&served),
                "budget {budget_mib} MiB must not change results"
            );
        }
    }

    #[test]
    fn validates_like_the_offline_model() {
        let (wt, ck, params) = table(50, 8, 6);
        let m = ShardedTopicModel::from_table(&wt, ck.clone(), params, 5, 0.0).unwrap();
        let err = m.infer(&[BowDoc::new(vec![5000])]).map(|_| ()).unwrap_err().to_string();
        assert!(err.contains("vocabulary"), "{err}");
        assert!(m.infer(&[]).unwrap().is_empty());
        // Construction guards: bad block counts, negative budget.
        assert!(ShardedTopicModel::from_table(&wt, ck.clone(), params, 0, 0.0).is_err());
        assert!(ShardedTopicModel::from_table(&wt, ck.clone(), params, 51, 0.0).is_err());
        assert!(ShardedTopicModel::from_table(&wt, ck, params, 5, -1.0).is_err());
    }

    #[test]
    fn touch_blocks_amortizes_and_ignores_junk() {
        let (wt, ck, params) = table(40, 8, 7);
        let m = ShardedTopicModel::from_table(&wt, ck, params, 4, 0.0).unwrap();
        let qs = docs(40, 6, 20, 13);
        let wanted = m.blocks_of(&qs);
        assert!(!wanted.is_empty() && wanted.windows(2).all(|w| w[0] < w[1]));
        m.touch_blocks(&wanted);
        let before = m.cache_stats();
        assert_eq!(before.misses, wanted.len() as u64);
        // Junk ids are ignored, not fatal.
        m.touch_blocks(&[999]);
        // The warmed batch now runs hit-only.
        m.infer(&qs).unwrap();
        let after = m.cache_stats();
        assert_eq!(after.misses, before.misses, "warmed batch must not re-fetch");
        assert!(after.hits > before.hits);
    }

    #[test]
    fn disk_stats_track_recalls_from_a_spilled_store() {
        use crate::storage::{Encoding, StorageOptions};
        // A store with no disk tier reports zeros.
        let (wt, ck, params) = table(80, 8, 9);
        let plain = ShardedTopicModel::from_table(&wt, ck.clone(), params, 8, 0.0).unwrap();
        let zero = plain.disk_stats();
        assert!(!zero.attached);
        assert_eq!((zero.recalls, zero.spill_bytes, zero.recall_bytes), (0, 0, 0));
        assert_eq!(zero.recall_p99_ms, 0.0);

        // Same model behind a fully starved out-of-core store: a 1-byte
        // budget spills every block, so serving pages each one off disk.
        let map = BlockMap::strided(80, 8);
        let blocks = Assignments::build_blocks(&wt, &map);
        let spec = ClusterSpec::from_config(&ClusterConfig {
            machines: 1,
            ..ClusterConfig::default()
        });
        let shards = ShardMap::round_robin(8, &spec);
        let mut kv = KvStore::new(blocks, ck.clone(), shards);
        let dir = std::env::temp_dir().join(format!("mplda_serve_disk_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        kv.attach_storage(StorageOptions {
            dir: dir.clone(),
            budget_bytes: 1,
            encoding: Encoding::Sparse,
        })
        .unwrap();
        let m = ShardedTopicModel::new(kv, map, params, 80, 0.0).unwrap();
        let before = m.disk_stats();
        assert!(before.attached);
        assert!(before.spill_bytes > 0, "a 1-byte budget must spill everything");
        assert_eq!(before.recalls, 0, "no serving traffic yet");

        // Served results still equal the offline model, and the recalls
        // show up in the counters and the latency histogram.
        let offline = crate::engine::TopicModel::new(wt.clone(), ck, params).unwrap();
        let qs = docs(80, 6, 25, 23);
        let opts = InferOptions { iterations: 5, seed: 7, threads: 1 };
        let reference = offline.infer_with(&qs, &opts).unwrap();
        let served = m.infer_with(&qs, &opts).unwrap();
        let snap = |dt: &DocTopics| -> Vec<Vec<(u32, u32)>> {
            (0..dt.len()).map(|d| dt.counts(d).iter().collect()).collect()
        };
        assert_eq!(snap(&reference), snap(&served), "spilled serving must stay bitwise equal");
        let after = m.disk_stats();
        assert!(after.recalls > 0, "spilled blocks must have been recalled");
        assert!(after.recall_bytes > 0);
        assert!(after.recall_p99_ms > 0.0, "recall latencies must be recorded");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_faults_fail_the_request_typed_then_clear() {
        use crate::error::MpldaError;
        let (wt, ck, params) = table(60, 8, 8);
        let m = ShardedTopicModel::from_table(&wt, ck, params, 6, 0.0).unwrap();
        let qs = docs(60, 3, 15, 17);
        for id in m.blocks_of(&qs) {
            m.store().inject_read_fault(id, 1000);
        }
        // The pre-pass turns the store fault into a typed request error;
        // nothing panics and the cache stays clean.
        let err = m.infer(&qs).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<MpldaError>(), Some(MpldaError::ReadFault { .. })),
            "{err:#}"
        );
        assert_eq!(m.cache_stats().resident_blocks, 0);
        // Clearing the faults makes the same request succeed.
        m.store().clear_read_faults();
        let folded = m.infer(&qs).unwrap();
        assert_eq!(folded.len(), qs.len());
    }
}
