//! Micro-batching request queue: group queued documents **by block** so
//! each block fetch is amortized across the whole batch — the training
//! rotation's model-parallelism, replayed at query time.
//!
//! Requests enqueue on a [`Batcher`]; the batch executor
//! ([`run_executor`]) cuts a batch when either `max_batch` documents are
//! queued or the oldest request has waited `max_wait` (the classic
//! throughput/latency dial). Before any document samples, the executor
//! touches every distinct block the batch needs once, in ascending id
//! order ([`super::model::ShardedTopicModel::touch_blocks`]) — with a
//! cache larger than the working set that pre-pass is the *only* paging
//! the batch pays, and with a starved cache it degrades gracefully to
//! per-token paging, still correct.
//!
//! **Batching never changes results.** Every request's documents sample
//! on RNG streams keyed by `(request seed, position within the request)`
//! — the same streams the offline model uses for that request as a
//! standalone batch — so any grouping of requests into batches, any
//! `max_batch`, and any number of front-end threads produce bitwise
//! identical `DocTopics` (`tests/serve_determinism.rs`).

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::{BowDoc, DocTopics};
use crate::sampler::Scratch;

use super::metrics::ServeMetrics;
use super::model::ShardedTopicModel;

/// One inference request: a document batch plus its RNG seed and Gibbs
/// sweep count. Equivalent offline call:
/// `TopicModel::infer_with(&docs, &InferOptions { seed, iterations, .. })`.
#[derive(Debug, Clone)]
pub struct InferRequest {
    /// The held-out documents to fold in.
    pub docs: Vec<BowDoc>,
    /// Seed of the per-document RNG streams (stream = position in
    /// `docs`).
    pub seed: u64,
    /// Gibbs sweeps per document.
    pub iterations: usize,
}

/// Batch-cutting knobs (config: `serve.max_batch` / `serve.max_wait_ms`).
#[derive(Debug, Clone, Copy)]
pub struct BatchOpts {
    /// Most documents a batch gathers before it is cut. A request's
    /// documents are never split across batches, so one oversized request
    /// still forms a single batch.
    pub max_batch: usize,
    /// Longest the oldest queued request may wait before the batch is cut
    /// anyway.
    pub max_wait: Duration,
}

impl Default for BatchOpts {
    fn default() -> Self {
        BatchOpts { max_batch: 32, max_wait: Duration::from_millis(5) }
    }
}

/// A queued request with its reply channel and enqueue time (latency is
/// measured enqueue → reply).
pub(crate) struct Pending {
    pub(crate) req: InferRequest,
    pub(crate) tx: Sender<Result<DocTopics>>,
    pub(crate) enqueued: Instant,
}

struct QueueState {
    queue: VecDeque<Pending>,
    closed: bool,
}

/// The shared request queue between front-end threads (producers) and
/// the batch executor (consumer).
pub struct Batcher {
    state: Mutex<QueueState>,
    cond: Condvar,
    opts: BatchOpts,
}

impl Batcher {
    /// An empty queue with the given batch-cutting knobs.
    pub fn new(opts: BatchOpts) -> Batcher {
        Batcher {
            state: Mutex::new(QueueState { queue: VecDeque::new(), closed: false }),
            cond: Condvar::new(),
            opts,
        }
    }

    /// Enqueue a request; the reply arrives on the returned channel once
    /// the executor has folded the documents in. After [`Batcher::close`]
    /// the reply is an immediate shutdown error.
    pub fn submit(&self, req: InferRequest) -> Receiver<Result<DocTopics>> {
        let (tx, rx) = channel();
        let mut st = self.state.lock().expect("batcher lock poisoned");
        if st.closed {
            let _ = tx.send(Err(anyhow::anyhow!("serving tier is shutting down")));
        } else {
            st.queue.push_back(Pending { req, tx, enqueued: Instant::now() });
            self.cond.notify_all();
        }
        rx
    }

    /// Stop accepting requests and wake the executor so it drains the
    /// queue and exits.
    pub fn close(&self) {
        self.state.lock().expect("batcher lock poisoned").closed = true;
        self.cond.notify_all();
    }

    /// Queued (not yet executed) requests right now.
    pub fn queued(&self) -> usize {
        self.state.lock().expect("batcher lock poisoned").queue.len()
    }

    /// Block until a batch is ready and cut it: whole requests in FIFO
    /// order until `max_batch` documents are gathered. Returns `None`
    /// once closed *and* drained.
    pub(crate) fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut st = self.state.lock().expect("batcher lock poisoned");
        loop {
            if st.queue.is_empty() {
                if st.closed {
                    return None;
                }
                st = self.cond.wait(st).expect("batcher lock poisoned");
                continue;
            }
            let docs_queued: usize = st.queue.iter().map(|p| p.req.docs.len()).sum();
            let oldest = st.queue.front().expect("queue non-empty").enqueued.elapsed();
            if st.closed || docs_queued >= self.opts.max_batch || oldest >= self.opts.max_wait {
                let mut batch = Vec::new();
                let mut docs = 0usize;
                loop {
                    let take = match st.queue.front() {
                        Some(p) => {
                            batch.is_empty() || docs + p.req.docs.len() <= self.opts.max_batch
                        }
                        None => false,
                    };
                    if !take {
                        break;
                    }
                    let p = st.queue.pop_front().expect("front was Some");
                    docs += p.req.docs.len();
                    batch.push(p);
                    if docs >= self.opts.max_batch {
                        break;
                    }
                }
                return Some(batch);
            }
            // Not full yet: sleep until the oldest request's deadline (or
            // a new arrival re-evaluates the cut conditions).
            let (guard, _) = self
                .cond
                .wait_timeout(st, self.opts.max_wait - oldest)
                .expect("batcher lock poisoned");
            st = guard;
        }
    }
}

/// The batch executor loop: cut batches until the queue closes, amortize
/// block paging with the group-by-block pre-pass, fold each request in on
/// its own RNG streams, and reply. One long-lived [`Scratch`] serves
/// every request — the serving hot path allocates nothing once warmed
/// (`tests/scratch_lifecycle.rs` proves the same property for the infer
/// core).
pub fn run_executor(model: &ShardedTopicModel, batcher: &Batcher, metrics: &ServeMetrics) {
    let mut scratch = Scratch::new(model.num_topics());
    while let Some(batch) = batcher.next_batch() {
        // Group-by-block pre-pass over the whole batch: each distinct
        // block is paged at most once however many documents touch it.
        let ids = model.blocks_of(batch.iter().flat_map(|p| p.req.docs.iter()));
        model.touch_blocks(&ids);
        metrics.record_batch();

        for p in batch {
            let result =
                model.fold_in_request(&p.req.docs, p.req.seed, p.req.iterations, &mut scratch);
            let docs = p.req.docs.len() as u64;
            let tokens: u64 = p.req.docs.iter().map(|d| d.len() as u64).sum();
            metrics.record_request(p.enqueued.elapsed().as_micros() as u64, docs, tokens);
            // The requester may have hung up; serving continues either way.
            let _ = p.tx.send(result);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(ndocs: usize, seed: u64) -> InferRequest {
        InferRequest {
            docs: (0..ndocs).map(|i| BowDoc::new(vec![i as u32])).collect(),
            seed,
            iterations: 1,
        }
    }

    #[test]
    fn cuts_on_max_batch_without_waiting() {
        let b = Batcher::new(BatchOpts { max_batch: 4, max_wait: Duration::from_secs(60) });
        let _r1 = b.submit(req(2, 1));
        let _r2 = b.submit(req(2, 2));
        let _r3 = b.submit(req(3, 3));
        // 4 docs queued from the first two requests: cut immediately, the
        // third request stays queued for the next batch.
        let batch = b.next_batch().expect("batch ready");
        assert_eq!(batch.len(), 2);
        assert_eq!(b.queued(), 1);
        assert_eq!(batch.iter().map(|p| p.req.docs.len()).sum::<usize>(), 4);
    }

    #[test]
    fn oversized_request_forms_its_own_batch() {
        let b = Batcher::new(BatchOpts { max_batch: 2, max_wait: Duration::from_secs(60) });
        let _r = b.submit(req(7, 1));
        let batch = b.next_batch().expect("batch ready");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].req.docs.len(), 7);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn cuts_on_deadline_when_underfull() {
        let b = Batcher::new(BatchOpts { max_batch: 1000, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        let _r = b.submit(req(1, 1));
        let batch = b.next_batch().expect("batch ready");
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(5), "must respect max_wait");
    }

    #[test]
    fn close_drains_then_ends() {
        let b = Batcher::new(BatchOpts { max_batch: 1000, max_wait: Duration::from_secs(60) });
        let _r = b.submit(req(1, 1));
        b.close();
        // Closed: queued work is still delivered, then the stream ends.
        assert!(b.next_batch().is_some());
        assert!(b.next_batch().is_none());
        // New submissions fail fast with a shutdown error.
        let rx = b.submit(req(1, 2));
        let reply = rx.recv().expect("immediate error reply");
        assert!(reply.unwrap_err().to_string().contains("shutting down"));
    }
}
