//! Length-prefixed framing shared by every TCP surface, in two flavors:
//! JSON frames (the original codec) and raw binary frames.
//!
//! A frame is a 4-byte big-endian prefix followed by that many body
//! bytes. The prefix's **top bit selects the frame kind**: clear = UTF-8
//! JSON (every frame the serve front end and the distributed control
//! plane exchange — byte-identical to the pre-binary protocol), set =
//! raw binary (the distributed trainer's task/result hot path, carrying
//! `model::wire` bytes directly instead of hex-in-JSON). The bit is free
//! to take because frame caps stay far below 2³¹. The codec grew up
//! inside `serve::server` and was lifted here when the distributed
//! trainer started speaking the same wire format — both sides share one
//! cap discipline and one set of typed errors:
//!
//! * a prefix larger than the cap ([`MAX_FRAME`] by default; the
//!   distributed tier passes `dist.max_frame_mib` through the `_with_cap`
//!   variants) fails with [`MpldaError::FrameTooLarge`] **before** the
//!   body buffer is allocated, so garbage or hostile prefixes can never
//!   trigger a multi-GiB allocation;
//! * EOF *between* frames is a clean end-of-stream (`Ok(None)`); EOF
//!   *inside* the length prefix is [`MpldaError::FrameTruncated`]; EOF
//!   inside the body surfaces the underlying `UnexpectedEof` I/O error.
//!
//! Malformed input is always a typed `Err`, never a panic —
//! `tests/prop_protocol.rs` drives the codec with truncations, garbage
//! and oversized prefixes to hold that line.

use std::io::{ErrorKind, Read, Write};

use anyhow::{bail, Context, Result};

use crate::error::MpldaError;

use super::json::Json;

/// Default upper bound on one frame's body (guards against garbage
/// prefixes). The distributed tier can raise it per-connection via
/// `dist.max_frame_mib`; JSON-only surfaces (the serve front end) always
/// use this value.
pub const MAX_FRAME: usize = 64 << 20;

/// Prefix bit marking a binary frame. Caps never reach 2³¹, so a length
/// with this bit set is unambiguous.
const BINARY_BIT: u32 = 1 << 31;

/// One decoded frame: the kind the peer sent decides how the body was
/// parsed.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// A UTF-8 JSON frame (prefix top bit clear).
    Json(Json),
    /// A raw binary frame (prefix top bit set).
    Binary(Vec<u8>),
}

/// Write one length-prefixed JSON frame (default cap).
pub fn write_frame<W: Write>(w: &mut W, body: &Json) -> Result<()> {
    write_frame_with_cap(w, body, MAX_FRAME).map(|_| ())
}

/// Write one length-prefixed JSON frame under an explicit cap; returns
/// total wire bytes written (prefix + body) for traffic accounting.
pub fn write_frame_with_cap<W: Write>(w: &mut W, body: &Json, cap: usize) -> Result<u64> {
    let text = body.render();
    if text.len() > cap {
        bail!("response frame of {} bytes exceeds the {cap}-byte cap", text.len());
    }
    w.write_all(&(text.len() as u32).to_be_bytes()).context("writing frame length")?;
    w.write_all(text.as_bytes()).context("writing frame body")?;
    w.flush().context("flushing frame")?;
    Ok(4 + text.len() as u64)
}

/// Write one binary frame (prefix top bit set) under an explicit cap;
/// returns total wire bytes written (prefix + body).
pub fn write_binary_frame<W: Write>(w: &mut W, body: &[u8], cap: usize) -> Result<u64> {
    let cap = cap.min(BINARY_BIT as usize - 1);
    if body.len() > cap {
        bail!("binary frame of {} bytes exceeds the {cap}-byte cap", body.len());
    }
    w.write_all(&(body.len() as u32 | BINARY_BIT).to_be_bytes())
        .context("writing frame length")?;
    w.write_all(body).context("writing frame body")?;
    w.flush().context("flushing frame")?;
    Ok(4 + body.len() as u64)
}

/// Fill the 4-byte length prefix byte-wise so EOF *before* a frame
/// (clean disconnect, `Ok(None)`) is distinguishable from EOF *inside*
/// the prefix (a truncated frame — a real framing error).
fn read_prefix<R: Read>(r: &mut R) -> Result<Option<u32>> {
    let mut len_bytes = [0u8; 4];
    let mut filled = 0usize;
    while filled < len_bytes.len() {
        match r.read(&mut len_bytes[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(None);
                }
                return Err(MpldaError::FrameTruncated { got: filled }.into());
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(u32::from_be_bytes(len_bytes)))
}

/// Read a `len`-byte body, rejecting the claim against `cap` *before*
/// allocation — the prefix is data from the wire, not a trusted size;
/// reject it before `vec![0u8; len]` commits gigabytes to a lie.
fn read_body<R: Read>(r: &mut R, len: usize, cap: usize) -> Result<Vec<u8>> {
    if len > cap {
        return Err(MpldaError::FrameTooLarge { len: len as u64 }.into());
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("reading frame body")?;
    Ok(body)
}

/// Read one frame's length prefix and body under `cap`, reporting the
/// kind bit. `Ok(None)` on clean EOF before a frame starts.
fn read_frame_raw<R: Read>(r: &mut R, cap: usize) -> Result<Option<(bool, Vec<u8>)>> {
    let Some(raw) = read_prefix(r)? else { return Ok(None) };
    let binary = raw & BINARY_BIT != 0;
    let body = read_body(r, (raw & !BINARY_BIT) as usize, cap)?;
    Ok(Some((binary, body)))
}

/// Read one frame's raw body under the default cap; `Ok(None)` on clean
/// EOF before a frame starts (the peer is done). Errors here mean the
/// *framing* is broken — the stream can no longer be trusted. A binary
/// frame from the peer is rejected as oversized (its prefix reads above
/// the cap with the kind bit folded in), which keeps JSON-only surfaces
/// honest without a new error variant.
pub fn read_frame_bytes<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    read_frame_raw_jsononly(r, MAX_FRAME)
}

/// JSON-only read: a set kind bit is *not* masked — the whole prefix is
/// compared against the cap, so binary frames surface as
/// [`MpldaError::FrameTooLarge`] exactly as any garbage prefix would.
fn read_frame_raw_jsononly<R: Read>(r: &mut R, cap: usize) -> Result<Option<Vec<u8>>> {
    let Some(raw) = read_prefix(r)? else { return Ok(None) };
    read_body(r, raw as usize, cap).map(Some)
}

/// Read one length-prefixed JSON frame; `Ok(None)` on clean EOF before a
/// frame starts (the peer is done).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Json>> {
    match read_frame_bytes(r)? {
        None => Ok(None),
        Some(body) => parse_json_body(&body).map(Some),
    }
}

/// Read one frame of either kind under an explicit cap; `Ok(None)` on
/// clean EOF before a frame starts. The distributed data plane uses this
/// so a control-plane JSON frame and a binary task/result frame can share
/// one socket. Returns the frame plus its total wire size (prefix +
/// body) for traffic accounting.
pub fn read_frame_any<R: Read>(r: &mut R, cap: usize) -> Result<Option<(Frame, u64)>> {
    match read_frame_raw(r, cap)? {
        None => Ok(None),
        Some((true, body)) => {
            let wire = 4 + body.len() as u64;
            Ok(Some((Frame::Binary(body), wire)))
        }
        Some((false, body)) => {
            let wire = 4 + body.len() as u64;
            Ok(Some((Frame::Json(parse_json_body(&body)?), wire)))
        }
    }
}

fn parse_json_body(body: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(body).context("frame body is not UTF-8")?;
    Json::parse(text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oversized_prefix_is_typed_and_never_allocated() {
        // A multi-GiB claim in 6 bytes of input: the typed rejection must
        // arrive without the 3 GiB body buffer ever existing.
        let mut r: &[u8] = &(3u32 << 30).to_be_bytes()[..];
        let err = read_frame(&mut r).unwrap_err();
        match err.downcast_ref::<MpldaError>() {
            Some(&MpldaError::FrameTooLarge { len }) => assert_eq!(len, (3u64) << 30),
            other => panic!("expected FrameTooLarge, got {other:?} in {err:#}"),
        }
    }

    #[test]
    fn mid_prefix_eof_is_typed() {
        let mut r: &[u8] = &[0, 0, 1];
        let err = read_frame(&mut r).unwrap_err();
        match err.downcast_ref::<MpldaError>() {
            Some(&MpldaError::FrameTruncated { got }) => assert_eq!(got, 3),
            other => panic!("expected FrameTruncated, got {other:?} in {err:#}"),
        }
    }

    #[test]
    fn exactly_max_frame_passes_the_cap() {
        // The cap is inclusive: a body of exactly MAX_FRAME bytes reads.
        // (Built as raw bytes — rendering a 64 MiB Json would dwarf the
        // point of the test.)
        let mut buf = (MAX_FRAME as u32).to_be_bytes().to_vec();
        buf.resize(4 + MAX_FRAME, b' ');
        let mut r = &buf[..];
        let body = read_frame_bytes(&mut r).unwrap().unwrap();
        assert_eq!(body.len(), MAX_FRAME);
        let mut r: &[u8] = &(MAX_FRAME as u32 + 1).to_be_bytes()[..];
        assert!(matches!(
            read_frame_bytes(&mut r).unwrap_err().downcast_ref::<MpldaError>(),
            Some(&MpldaError::FrameTooLarge { .. })
        ));
    }

    #[test]
    fn binary_frames_roundtrip_and_carry_their_kind() {
        let mut buf = Vec::new();
        let wrote = write_binary_frame(&mut buf, &[1, 2, 3, 255], MAX_FRAME).unwrap();
        assert_eq!(wrote, 8);
        let mut r = &buf[..];
        let (frame, wire) = read_frame_any(&mut r, MAX_FRAME).unwrap().unwrap();
        assert_eq!(wire, 8);
        assert_eq!(frame, Frame::Binary(vec![1, 2, 3, 255]));
        // Empty binary frame is legal (prefix carries only the kind bit).
        let mut buf = Vec::new();
        write_binary_frame(&mut buf, &[], MAX_FRAME).unwrap();
        let mut r = &buf[..];
        let (frame, _) = read_frame_any(&mut r, MAX_FRAME).unwrap().unwrap();
        assert_eq!(frame, Frame::Binary(Vec::new()));
    }

    #[test]
    fn json_frames_read_identically_through_both_entry_points() {
        let j = Json::parse(r#"{"type":"register"}"#).unwrap();
        let mut buf = Vec::new();
        let wrote = write_frame_with_cap(&mut buf, &j, MAX_FRAME).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), j);
        let mut r = &buf[..];
        let (frame, wire) = read_frame_any(&mut r, MAX_FRAME).unwrap().unwrap();
        assert_eq!(frame, Frame::Json(j));
        assert_eq!(wire, wrote);
    }

    #[test]
    fn json_only_reader_rejects_binary_frames() {
        // The serve front end never learned binary: a binary frame's
        // prefix reads as a > 2 GiB length and dies typed, pre-alloc.
        let mut buf = Vec::new();
        write_binary_frame(&mut buf, b"payload", MAX_FRAME).unwrap();
        let mut r = &buf[..];
        let err = read_frame(&mut r).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<MpldaError>(),
            Some(&MpldaError::FrameTooLarge { .. })
        ));
    }

    #[test]
    fn caps_are_per_call() {
        let mut buf = Vec::new();
        write_binary_frame(&mut buf, &[0u8; 2048], 4096).unwrap();
        // A reader with a smaller cap rejects it typed.
        let mut r = &buf[..];
        let err = read_frame_any(&mut r, 1024).unwrap_err();
        match err.downcast_ref::<MpldaError>() {
            Some(&MpldaError::FrameTooLarge { len }) => assert_eq!(len, 2048),
            other => panic!("expected FrameTooLarge, got {other:?}"),
        }
        // A writer over its own cap refuses to send.
        let mut sink = Vec::new();
        assert!(write_binary_frame(&mut sink, &[0u8; 2048], 1024).is_err());
        assert!(sink.is_empty(), "nothing hits the wire on a refused frame");
    }
}
