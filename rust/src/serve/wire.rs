//! Length-prefixed JSON framing shared by every TCP surface.
//!
//! One frame is a 4-byte big-endian length followed by that many bytes of
//! UTF-8 JSON. The codec grew up inside `serve::server` (the online
//! inference front end) and was lifted here when the distributed trainer
//! (`crate::distributed`) started speaking the same wire format — both
//! sides now share one cap, one EOF discipline, and one set of typed
//! errors:
//!
//! * a prefix larger than [`MAX_FRAME`] fails with
//!   [`MpldaError::FrameTooLarge`] **before** the body buffer is
//!   allocated, so garbage or hostile prefixes can never trigger a
//!   multi-GiB allocation;
//! * EOF *between* frames is a clean end-of-stream (`Ok(None)`); EOF
//!   *inside* the length prefix is [`MpldaError::FrameTruncated`]; EOF
//!   inside the body surfaces the underlying `UnexpectedEof` I/O error.
//!
//! Malformed input is always a typed `Err`, never a panic —
//! `tests/prop_protocol.rs` drives the codec with truncations, garbage
//! and oversized prefixes to hold that line.

use std::io::{ErrorKind, Read, Write};

use anyhow::{bail, Context, Result};

use crate::error::MpldaError;

use super::json::Json;

/// Upper bound on one frame's body (guards against garbage prefixes).
pub const MAX_FRAME: usize = 64 << 20;

/// Write one length-prefixed JSON frame.
pub fn write_frame<W: Write>(w: &mut W, body: &Json) -> Result<()> {
    let text = body.render();
    if text.len() > MAX_FRAME {
        bail!("response frame of {} bytes exceeds the {MAX_FRAME}-byte cap", text.len());
    }
    w.write_all(&(text.len() as u32).to_be_bytes()).context("writing frame length")?;
    w.write_all(text.as_bytes()).context("writing frame body")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one frame's raw body; `Ok(None)` on clean EOF before a frame
/// starts (the peer is done). Errors here mean the *framing* is broken —
/// the stream can no longer be trusted.
pub fn read_frame_bytes<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    // Fill the length prefix byte-wise so EOF *before* a frame (clean
    // disconnect) is distinguishable from EOF *inside* the prefix (a
    // truncated frame — a real framing error).
    let mut len_bytes = [0u8; 4];
    let mut filled = 0usize;
    while filled < len_bytes.len() {
        match r.read(&mut len_bytes[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(None);
                }
                return Err(MpldaError::FrameTruncated { got: filled }.into());
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_be_bytes(len_bytes) as usize;
    if len > MAX_FRAME {
        // The prefix is data from the wire, not a trusted size: reject it
        // before `vec![0u8; len]` commits gigabytes to a lie.
        return Err(MpldaError::FrameTooLarge { len: len as u64 }.into());
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("reading frame body")?;
    Ok(Some(body))
}

/// Read one length-prefixed JSON frame; `Ok(None)` on clean EOF before a
/// frame starts (the peer is done).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Json>> {
    match read_frame_bytes(r)? {
        None => Ok(None),
        Some(body) => {
            let text = std::str::from_utf8(&body).context("frame body is not UTF-8")?;
            Json::parse(text).map(Some)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oversized_prefix_is_typed_and_never_allocated() {
        // A multi-GiB claim in 6 bytes of input: the typed rejection must
        // arrive without the 3 GiB body buffer ever existing.
        let mut r: &[u8] = &(3u32 << 30).to_be_bytes()[..];
        let err = read_frame(&mut r).unwrap_err();
        match err.downcast_ref::<MpldaError>() {
            Some(&MpldaError::FrameTooLarge { len }) => assert_eq!(len, (3u64) << 30),
            other => panic!("expected FrameTooLarge, got {other:?} in {err:#}"),
        }
    }

    #[test]
    fn mid_prefix_eof_is_typed() {
        let mut r: &[u8] = &[0, 0, 1];
        let err = read_frame(&mut r).unwrap_err();
        match err.downcast_ref::<MpldaError>() {
            Some(&MpldaError::FrameTruncated { got }) => assert_eq!(got, 3),
            other => panic!("expected FrameTruncated, got {other:?} in {err:#}"),
        }
    }

    #[test]
    fn exactly_max_frame_passes_the_cap() {
        // The cap is inclusive: a body of exactly MAX_FRAME bytes reads.
        // (Built as raw bytes — rendering a 64 MiB Json would dwarf the
        // point of the test.)
        let mut buf = (MAX_FRAME as u32).to_be_bytes().to_vec();
        buf.resize(4 + MAX_FRAME, b' ');
        let mut r = &buf[..];
        let body = read_frame_bytes(&mut r).unwrap().unwrap();
        assert_eq!(body.len(), MAX_FRAME);
        let mut r: &[u8] = &(MAX_FRAME as u32 + 1).to_be_bytes()[..];
        assert!(matches!(
            read_frame_bytes(&mut r).unwrap_err().downcast_ref::<MpldaError>(),
            Some(&MpldaError::FrameTooLarge { .. })
        ));
    }
}
