//! The TCP front end: a dependency-free `std::net` server speaking
//! length-prefixed JSON, layered on the in-process [`Harness`].
//!
//! ## Wire format
//!
//! Every message — both directions — is one frame:
//!
//! ```text
//! ┌────────────────────┬──────────────────────────────┐
//! │ length: u32 (BE)   │ body: `length` bytes of JSON │
//! └────────────────────┴──────────────────────────────┘
//! ```
//!
//! Requests (`"type"` selects the verb):
//!
//! | request                                                        | response |
//! |----------------------------------------------------------------|----------|
//! | `{"type":"ping"}`                                              | `{"type":"pong"}` |
//! | `{"type":"infer","docs":[[w,…],…],"seed":S,"iterations":N}`    | `{"type":"result","counts":[[[topic,count],…],…]}` |
//! | `{"type":"stats"}`                                             | `{"type":"stats", …counters…}` (see [`StatsSnapshot::to_json`]) |
//! | `{"type":"metrics"}`                                           | `{"type":"metrics","body":"…"}` — Prometheus text exposition |
//! | `{"type":"shutdown"}`                                          | `{"type":"bye"}`, then the server stops |
//!
//! `seed` and `iterations` are optional (defaults: seed 0, the
//! configured `serve.iterations`). Malformed JSON or unknown verbs get
//! `{"type":"error","message":…}` and the connection stays open; framing
//! errors close the connection.
//!
//! ## Threading
//!
//! One accept thread feeds a pool of `serve.threads` connection
//! handlers; all of them enqueue onto the shared micro-batcher, whose
//! single executor owns the sampling. Results are independent of the
//! pool size — per-request RNG streams, see [`super::batcher`].

use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use crate::config::ServeConfig;
use crate::engine::{BowDoc, DocTopics};

use super::batcher::{BatchOpts, Batcher, InferRequest};
use super::harness::Harness;
use super::json::Json;
use super::metrics::{ServeMetrics, StatsSnapshot};
use super::model::ShardedTopicModel;

// The framing codec (cap, typed errors, EOF discipline) lives in the
// shared `wire` module since the distributed trainer adopted the same
// format; re-exported so this module remains the serving tier's one-stop
// wire surface.
pub use super::wire::{read_frame, read_frame_bytes, write_frame};

/// Upper bound on client-requested Gibbs sweeps. The executor is shared;
/// without a cap one request could wedge it (and teardown) for an
/// arbitrary multiple of its document cost. The default is 20; anything
/// past this is a client error, not a workload.
const MAX_REQUEST_ITERATIONS: usize = 1_000;

fn error_frame(message: impl std::fmt::Display) -> Json {
    Json::Obj(vec![
        ("type".into(), Json::str("error")),
        ("message".into(), Json::str(message.to_string())),
    ])
}

/// Render served [`DocTopics`] as the `result` response: per document,
/// the folded-in `(topic, count)` pairs in their live (descending-count)
/// order — exact integers, so clients can digest-compare across servers.
fn result_frame(folded: &DocTopics) -> Json {
    let docs: Vec<Json> = (0..folded.len())
        .map(|d| {
            Json::Arr(
                folded
                    .counts(d)
                    .iter()
                    .map(|(t, c)| Json::Arr(vec![Json::num(t as f64), Json::num(c as f64)]))
                    .collect(),
            )
        })
        .collect();
    Json::Obj(vec![("type".into(), Json::str("result")), ("counts".into(), Json::Arr(docs))])
}

fn parse_infer(req: &Json, default_iterations: usize) -> Result<InferRequest> {
    let docs_json = req.get("docs").and_then(Json::as_arr).context("infer needs \"docs\"")?;
    let mut docs = Vec::with_capacity(docs_json.len());
    for (i, doc) in docs_json.iter().enumerate() {
        let words = doc.as_arr().with_context(|| format!("doc {i} is not an array"))?;
        let mut tokens = Vec::with_capacity(words.len());
        for w in words {
            let id = w
                .as_u64()
                .with_context(|| format!("doc {i} has a non-integer word id"))?;
            if id > u32::MAX as u64 {
                bail!("doc {i} word id {id} exceeds u32");
            }
            tokens.push(id as u32);
        }
        docs.push(BowDoc::new(tokens));
    }
    let seed = match req.get("seed") {
        None => 0,
        Some(s) => s.as_u64().context("\"seed\" must be a non-negative integer")?,
    };
    let iterations = match req.get("iterations") {
        None => default_iterations,
        Some(n) => n.as_u64().context("\"iterations\" must be a non-negative integer")? as usize,
    };
    if iterations > MAX_REQUEST_ITERATIONS {
        bail!("iterations {iterations} exceeds the per-request cap of {MAX_REQUEST_ITERATIONS}");
    }
    Ok(InferRequest { docs, seed, iterations })
}

/// Per-connection state shared with the handler threads.
struct ConnCtx {
    model: Arc<ShardedTopicModel>,
    batcher: Arc<Batcher>,
    metrics: Arc<ServeMetrics>,
    shutdown: Arc<AtomicBool>,
    addr: SocketAddr,
    default_iterations: usize,
}

/// Serve one connection until EOF, a framing error, or shutdown.
fn handle_conn(mut stream: TcpStream, ctx: &ConnCtx) {
    loop {
        let body = match read_frame_bytes(&mut stream) {
            Ok(Some(body)) => body,
            Ok(None) => return, // clean EOF
            Err(e) => {
                // Framing is broken; report if possible, then drop.
                let _ = write_frame(&mut stream, &error_frame(e));
                return;
            }
        };
        // The body was fully consumed, so a malformed payload leaves the
        // framing intact: report and keep the connection open.
        let parsed = std::str::from_utf8(&body)
            .map_err(|e| anyhow::anyhow!("frame body is not UTF-8: {e}"))
            .and_then(|text| Json::parse(text));
        let request = match parsed {
            Ok(json) => json,
            Err(e) => {
                if write_frame(&mut stream, &error_frame(e)).is_err() {
                    return;
                }
                continue;
            }
        };
        let response = match request.get("type").and_then(Json::as_str) {
            Some("ping") => Json::Obj(vec![("type".into(), Json::str("pong"))]),
            Some("infer") => match parse_infer(&request, ctx.default_iterations) {
                Err(e) => error_frame(e),
                Ok(req) => {
                    let rx = ctx.batcher.submit(req);
                    match rx.recv() {
                        Err(_) => error_frame("serving executor hung up"),
                        Ok(Err(e)) => error_frame(e),
                        Ok(Ok(folded)) => result_frame(&folded),
                    }
                }
            },
            Some("stats") => ctx
                .metrics
                .snapshot(ctx.model.cache_stats(), ctx.model.disk_stats())
                .to_json(),
            Some("metrics") => {
                let body = ctx
                    .metrics
                    .snapshot(ctx.model.cache_stats(), ctx.model.disk_stats())
                    .to_prometheus(&ctx.metrics.latency_histogram(), &ctx.model.recall_histogram());
                Json::Obj(vec![
                    ("type".into(), Json::str("metrics")),
                    ("body".into(), Json::str(body)),
                ])
            }
            Some("shutdown") => {
                let _ = write_frame(&mut stream, &Json::Obj(vec![(
                    "type".into(),
                    Json::str("bye"),
                )]));
                ctx.shutdown.store(true, Ordering::SeqCst);
                // Poke the accept loop so it observes the flag.
                let _ = TcpStream::connect(ctx.addr);
                return;
            }
            _ => error_frame("unknown request type (ping|infer|stats|metrics|shutdown)"),
        };
        if write_frame(&mut stream, &response).is_err() {
            return; // peer went away mid-reply
        }
    }
}

/// A running serving front end. Built by [`Server::serve`]; stop it with
/// [`Server::shutdown`] (or a `shutdown` request + [`Server::join`]).
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    handlers: Vec<JoinHandle<()>>,
    /// Clones of every live connection, so teardown can force-close them
    /// — a handler blocked reading an idle client must still be joinable.
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    harness: Option<Harness>,
}

impl Server {
    /// Bind `127.0.0.1:{cfg.port}` (port 0 = ephemeral), spin up the
    /// serving stack (model, batcher, executor) and `cfg.threads`
    /// connection handlers, and start accepting.
    pub fn serve(model: ShardedTopicModel, cfg: &ServeConfig) -> Result<Server> {
        if cfg.port > u16::MAX as usize {
            bail!("serve.port {} does not fit in 16 bits (0 = ephemeral)", cfg.port);
        }
        let listener = TcpListener::bind(("127.0.0.1", cfg.port as u16))
            .with_context(|| format!("binding 127.0.0.1:{}", cfg.port))?;
        let addr = listener.local_addr().context("reading bound address")?;
        let opts = BatchOpts {
            max_batch: cfg.max_batch,
            max_wait: std::time::Duration::from_millis(cfg.max_wait_ms),
        };
        let harness = Harness::new(model, opts);
        let (model, batcher, metrics) = harness.shared();
        let shutdown = Arc::new(AtomicBool::new(false));

        // Connection pool: the accept thread feeds handlers over a
        // channel (a Receiver is single-consumer, so it rides a mutex).
        let (conn_tx, conn_rx): (Sender<TcpStream>, Receiver<TcpStream>) = channel();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let conns: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
        let next_conn_id = Arc::new(AtomicU64::new(0));
        let mut handlers = Vec::with_capacity(cfg.threads);
        for _ in 0..cfg.threads.max(1) {
            let conn_rx = Arc::clone(&conn_rx);
            let conns = Arc::clone(&conns);
            let next_conn_id = Arc::clone(&next_conn_id);
            let ctx = ConnCtx {
                model: Arc::clone(&model),
                batcher: Arc::clone(&batcher),
                metrics: Arc::clone(&metrics),
                shutdown: Arc::clone(&shutdown),
                addr,
                default_iterations: cfg.iterations,
            };
            handlers.push(std::thread::spawn(move || loop {
                // Take the next connection; when the accept thread drops
                // the sender, recv errors and the handler retires.
                let next = conn_rx.lock().expect("conn queue lock poisoned").recv();
                match next {
                    Ok(stream) => {
                        // Register before the shutdown check: any
                        // interleaving either registers in time for
                        // teardown's force-close sweep or observes the
                        // flag here — a blocked handler is always
                        // joinable.
                        let id = next_conn_id.fetch_add(1, Ordering::Relaxed);
                        if let Ok(peer) = stream.try_clone() {
                            conns.lock().expect("conn registry poisoned").insert(id, peer);
                        }
                        if !ctx.shutdown.load(Ordering::SeqCst) {
                            handle_conn(stream, &ctx);
                        }
                        conns.lock().expect("conn registry poisoned").remove(&id);
                    }
                    Err(_) => return,
                }
            }));
        }

        let accept = {
            let shutdown = Arc::clone(&shutdown);
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        return; // conn_tx drops here; handlers retire
                    }
                    match stream {
                        Ok(s) => {
                            if conn_tx.send(s).is_err() {
                                return;
                            }
                        }
                        Err(_) => continue,
                    }
                }
            })
        };

        log::info!(
            "serving on {addr} ({} handler threads, max_batch {}, max_wait {}ms, cache {})",
            cfg.threads,
            cfg.max_batch,
            cfg.max_wait_ms,
            if cfg.cache_budget_mib > 0.0 {
                format!("{} MiB", cfg.cache_budget_mib)
            } else {
                "unlimited".into()
            }
        );
        Ok(Server { addr, shutdown, accept: Some(accept), handlers, conns, harness: Some(harness) })
    }

    /// The bound address (reads back the OS-assigned port when
    /// `serve.port = 0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Current serving statistics.
    pub fn stats(&self) -> StatsSnapshot {
        self.harness.as_ref().expect("harness lives until teardown").stats()
    }

    /// The serving counters as Prometheus text — what the `metrics`
    /// request returns in its `body` field.
    pub fn prometheus(&self) -> String {
        self.harness.as_ref().expect("harness lives until teardown").prometheus()
    }

    /// The served model — the paging-fault tests reach
    /// [`crate::kvstore::KvStore::inject_read_fault`] through it while
    /// the server is live.
    pub fn model(&self) -> &ShardedTopicModel {
        self.harness.as_ref().expect("harness lives until teardown").model()
    }

    /// Block until the server stops (a `shutdown` request arrived or
    /// [`Server::shutdown`] ran), then tear the stack down in order:
    /// accept thread → handlers → batcher/executor.
    pub fn join(mut self) {
        self.teardown();
    }

    /// Stop accepting, finish in-flight work, and join every thread.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Unblock the accept loop.
        let _ = TcpStream::connect(self.addr);
        self.teardown();
    }

    fn teardown(&mut self) {
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
        // Force-close live connections: a handler blocked reading an idle
        // client sees EOF and retires instead of pinning join() forever.
        // (teardown only runs with the shutdown flag set, so handlers
        // won't pick up *new* connections past this sweep.)
        for (_, conn) in self.conns.lock().expect("conn registry poisoned").drain() {
            let _ = conn.shutdown(Shutdown::Both);
        }
        for handle in self.handlers.drain(..) {
            let _ = handle.join();
        }
        // Dropping the harness closes the batcher and joins the executor.
        self.harness.take();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        self.teardown();
    }
}

/// A small blocking client for the wire protocol — what the loopback
/// smoke test and operational scripts use.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    /// Connect to a running server.
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to mplda serve at {addr}"))?;
        Ok(Client { stream })
    }

    /// One request/response round trip.
    pub fn request(&mut self, body: &Json) -> Result<Json> {
        write_frame(&mut self.stream, body)?;
        read_frame(&mut self.stream)?.context("server closed the connection mid-request")
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        let reply = self.request(&Json::Obj(vec![("type".into(), Json::str("ping"))]))?;
        match reply.get("type").and_then(Json::as_str) {
            Some("pong") => Ok(()),
            _ => bail!("unexpected ping reply: {}", reply.render()),
        }
    }

    /// Fold in documents; returns per-document `(topic, count)` pairs.
    pub fn infer(
        &mut self,
        docs: &[Vec<u32>],
        seed: u64,
        iterations: usize,
    ) -> Result<Vec<Vec<(u32, u32)>>> {
        let docs_json = Json::Arr(
            docs.iter()
                .map(|d| Json::Arr(d.iter().map(|&w| Json::num(w as f64)).collect()))
                .collect(),
        );
        let reply = self.request(&Json::Obj(vec![
            ("type".into(), Json::str("infer")),
            ("seed".into(), Json::num(seed as f64)),
            ("iterations".into(), Json::num(iterations as f64)),
            ("docs".into(), docs_json),
        ]))?;
        match reply.get("type").and_then(Json::as_str) {
            Some("result") => {}
            Some("error") => bail!(
                "server error: {}",
                reply.get("message").and_then(Json::as_str).unwrap_or("?")
            ),
            _ => bail!("unexpected infer reply: {}", reply.render()),
        }
        let counts = reply.get("counts").and_then(Json::as_arr).context("reply has counts")?;
        let mut out = Vec::with_capacity(counts.len());
        for doc in counts {
            let pairs = doc.as_arr().context("doc counts are an array")?;
            let mut entries = Vec::with_capacity(pairs.len());
            for p in pairs {
                let pair = p.as_arr().context("count entry is a pair")?;
                if pair.len() != 2 {
                    bail!("count entry is not a (topic, count) pair");
                }
                let t = pair[0].as_u64().context("topic is an integer")?;
                let c = pair[1].as_u64().context("count is an integer")?;
                entries.push((t as u32, c as u32));
            }
            out.push(entries);
        }
        Ok(out)
    }

    /// Fetch the server's stats object.
    pub fn stats(&mut self) -> Result<Json> {
        let reply = self.request(&Json::Obj(vec![("type".into(), Json::str("stats"))]))?;
        match reply.get("type").and_then(Json::as_str) {
            Some("stats") => Ok(reply),
            _ => bail!("unexpected stats reply: {}", reply.render()),
        }
    }

    /// Fetch the server's metrics in Prometheus text exposition format.
    pub fn metrics(&mut self) -> Result<String> {
        let reply = self.request(&Json::Obj(vec![("type".into(), Json::str("metrics"))]))?;
        match reply.get("type").and_then(Json::as_str) {
            Some("metrics") => reply
                .get("body")
                .and_then(Json::as_str)
                .map(str::to_owned)
                .context("metrics reply has a \"body\" string"),
            _ => bail!("unexpected metrics reply: {}", reply.render()),
        }
    }

    /// Ask the server to stop (it finishes in-flight work first).
    pub fn shutdown(&mut self) -> Result<()> {
        let reply = self.request(&Json::Obj(vec![("type".into(), Json::str("shutdown"))]))?;
        match reply.get("type").and_then(Json::as_str) {
            Some("bye") => Ok(()),
            _ => bail!("unexpected shutdown reply: {}", reply.render()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_round_trip_over_a_buffer() {
        let msg = Json::Obj(vec![
            ("type".into(), Json::str("infer")),
            ("docs".into(), Json::Arr(vec![Json::Arr(vec![Json::num(3.0)])])),
        ]);
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &msg).unwrap();
        let body_len = (buf.len() - 4) as u32;
        assert_eq!(buf[..4], body_len.to_be_bytes()[..]);
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), Some(msg));
        // Clean EOF after the frame.
        assert_eq!(read_frame(&mut r).unwrap(), None);
    }

    #[test]
    fn read_frame_rejects_garbage() {
        // EOF before any frame is a clean end-of-stream …
        let mut r: &[u8] = &[];
        assert_eq!(read_frame(&mut r).unwrap(), None);
        // … but EOF inside the length prefix is a framing error.
        let mut r: &[u8] = &[0, 0];
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("mid-frame"), "{err}");
        // Absurd length prefix.
        let mut r: &[u8] = &[0xff, 0xff, 0xff, 0xff, 0, 0];
        assert!(read_frame(&mut r).is_err());
        // Truncated body.
        let mut buf = 10u32.to_be_bytes().to_vec();
        buf.extend_from_slice(b"abc");
        let mut r = &buf[..];
        assert!(read_frame(&mut r).is_err());
        // Non-JSON body.
        let mut buf = 3u32.to_be_bytes().to_vec();
        buf.extend_from_slice(b"zzz");
        let mut r = &buf[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn parse_infer_defaults_and_validation() {
        let req = Json::parse(r#"{"type":"infer","docs":[[1,2],[3]]}"#).unwrap();
        let parsed = parse_infer(&req, 17).unwrap();
        assert_eq!(parsed.docs.len(), 2);
        assert_eq!(parsed.docs[0].tokens, vec![1, 2]);
        assert_eq!(parsed.seed, 0);
        assert_eq!(parsed.iterations, 17);

        let req =
            Json::parse(r#"{"type":"infer","docs":[[7]],"seed":9,"iterations":3}"#).unwrap();
        let parsed = parse_infer(&req, 17).unwrap();
        assert_eq!((parsed.seed, parsed.iterations), (9, 3));

        for bad in [
            r#"{"type":"infer"}"#,
            r#"{"type":"infer","docs":[0]}"#,
            r#"{"type":"infer","docs":[[1.5]]}"#,
            r#"{"type":"infer","docs":[[-1]]}"#,
            r#"{"type":"infer","docs":[[4294967296]]}"#,
            r#"{"type":"infer","docs":[[1]],"seed":-2}"#,
            // Over the sweep cap: one request must not wedge the executor.
            r#"{"type":"infer","docs":[[1]],"iterations":1000000}"#,
        ] {
            let req = Json::parse(bad).unwrap();
            assert!(parse_infer(&req, 17).is_err(), "{bad} should fail");
        }
    }
}
