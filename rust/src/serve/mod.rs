//! The `serve` subsystem — model-parallel **online inference**: fold-in
//! queries answered against a model that stays block-sharded in the
//! KV-store, never materialized densely.
//!
//! ```text
//!            TCP (length-prefixed JSON)            in process
//!  clients ──────────► server ───► batcher ───► executor ──► ShardedTopicModel
//!                        │            │  micro-batch,           │  LRU block cache
//!                        │            │  group-by-block         │  (serve.cache_budget_mib,
//!                        ▼            ▼                         ▼   MemCategory::ServeCache)
//!                     metrics ◄── latency/throughput        KvStore::read_block
//!                                    + cache hit rate       (read-only concurrent leases)
//! ```
//!
//! * [`model`] — [`ShardedTopicModel`]: pages `ModelBlock`s on demand
//!   through a budget-bounded LRU cache; a model larger than the cache
//!   serves correctly, just slower.
//! * [`batcher`] — micro-batching queue (`serve.max_batch`,
//!   `serve.max_wait_ms`) grouping queued documents' tokens by block, so
//!   each block fetch amortizes across the whole batch — the training
//!   rotation's model-parallelism replayed at query time.
//! * [`server`] — dependency-free `std::net` TCP front end
//!   (`mplda serve`) with a handler pool and a `stats` verb (latency
//!   percentiles, throughput, cache hit rate from [`metrics`]).
//! * [`wire`] — the length-prefixed JSON framing itself (frame cap,
//!   typed truncation/oversize errors), shared with the distributed
//!   trainer's master/worker protocol ([`crate::distributed`]).
//! * [`harness`] — the same stack with no sockets, driven by
//!   `tests/serve_determinism.rs` to prove served results **bitwise
//!   equal** offline `TopicModel::infer` at every cache budget, batch
//!   size and thread count.
//!
//! See DESIGN.md §Serving for the paging lifecycle, the cache budget
//! math, and the determinism argument; EXPERIMENTS.md §E9 for the
//! `serve_latency` bench and its acceptance bar.

pub mod batcher;
pub mod harness;
pub mod json;
pub mod metrics;
pub mod model;
pub mod server;
pub mod wire;

pub use batcher::{BatchOpts, Batcher, InferRequest};
pub use harness::Harness;
pub use json::Json;
pub use metrics::{LatencyHistogram, ServeMetrics, StatsSnapshot};
pub use model::{CacheStats, DiskStats, ShardedTopicModel};
pub use server::{Client, Server};
