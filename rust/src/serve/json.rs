//! Minimal dependency-free JSON for the serving wire format.
//!
//! The TCP front end ([`super::server`]) frames requests and responses as
//! length-prefixed JSON documents; this module supplies the value type,
//! a recursive-descent parser and a writer. It is deliberately small —
//! no serde, no derive, no borrowing parser — because the serving
//! protocol's payloads are shallow (word-id arrays, count pairs, stat
//! scalars) and the workspace builds offline with zero external crates.
//!
//! Numbers are carried as `f64`; exact integers up to 2^53 round-trip,
//! which covers word ids, counts, ports, and seeds as used on the wire.

use anyhow::{bail, Result};

/// One JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (integers round-trip exactly up to 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, insertion-ordered (the writer emits keys in this order).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Shorthand: a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Shorthand: a numeric value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Object field lookup (first match; `None` for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The value as `f64`, if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer, if it is one exactly.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9_007_199_254_740_992.0 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// The value as a string slice, if it is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as an array slice, if it is one.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Parse a JSON document (must consume the whole input).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos, 0)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing bytes after JSON value at offset {pos}");
        }
        Ok(value)
    }

    /// Render to compact JSON text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out);
        out
    }
}

const MAX_DEPTH: usize = 64;

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<()> {
    if b.len() >= *pos + lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(())
    } else {
        bail!("expected {lit:?} at offset {}", *pos);
    }
}

fn parse_value(b: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    if depth > MAX_DEPTH {
        bail!("JSON nested deeper than {MAX_DEPTH}");
    }
    skip_ws(b, pos);
    match b.get(*pos).copied() {
        None => bail!("unexpected end of JSON"),
        Some(b'n') => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        Some(b't') => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        Some(b'f') => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos, depth + 1)?);
                skip_ws(b, pos);
                match b.get(*pos).copied() {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => bail!("expected ',' or ']' at offset {}", *pos),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, ":")?;
                let value = parse_value(b, pos, depth + 1)?;
                fields.push((key, value));
                skip_ws(b, pos);
                match b.get(*pos).copied() {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => bail!("expected ',' or '}}' at offset {}", *pos),
                }
            }
        }
        Some(_) => parse_number(b, pos).map(Json::Num),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if b.get(*pos) != Some(&b'"') {
        bail!("expected string at offset {}", *pos);
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos).copied() {
            None => bail!("unterminated string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos).copied() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        if b.len() < *pos + 5 {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .ok()
                            .and_then(|h| u32::from_str_radix(h, 16).ok());
                        let cp = hex.with_offset(*pos)?;
                        // BMP only — the writer never emits surrogate
                        // escapes (it writes UTF-8 directly).
                        match char::from_u32(cp) {
                            Some(c) => out.push(c),
                            None => bail!("\\u escape is not a scalar value"),
                        }
                        *pos += 4;
                    }
                    _ => bail!("bad escape at offset {}", *pos),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (input validated as UTF-8 by
                // the caller taking &str).
                let rest = std::str::from_utf8(&b[*pos..]).map_err(|_| {
                    anyhow::Error::msg(format!("invalid UTF-8 at offset {}", *pos))
                })?;
                let c = rest.chars().next().unwrap();
                if (c as u32) < 0x20 {
                    bail!("unescaped control character in string");
                }
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

/// Tiny helper so the `\u` path reads linearly.
trait WithOffset {
    fn with_offset(self, pos: usize) -> Result<u32>;
}

impl WithOffset for Option<u32> {
    fn with_offset(self, pos: usize) -> Result<u32> {
        match self {
            Some(v) => Ok(v),
            None => bail!("bad \\u escape at offset {pos}"),
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<f64> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    if start == *pos {
        bail!("expected a JSON value at offset {start}");
    }
    let text = std::str::from_utf8(&b[start..*pos]).expect("digits are ASCII");
    match text.parse::<f64>() {
        Ok(n) if n.is_finite() => Ok(n),
        _ => bail!("bad number {text:?} at offset {start}"),
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => write_number(*n, out),
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(fields) => {
            out.push('{');
            for (i, (k, item)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(item, out);
            }
            out.push('}');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; the protocol never produces them, but a
        // defensive null beats emitting an unparsable document.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() <= 9_007_199_254_740_992.0 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_protocol_shapes() {
        let doc = Json::Obj(vec![
            ("type".into(), Json::str("infer")),
            ("seed".into(), Json::num(61455.0)),
            (
                "docs".into(),
                Json::Arr(vec![
                    Json::Arr(vec![Json::num(0.0), Json::num(2.0), Json::num(2.0)]),
                    Json::Arr(vec![]),
                ]),
            ),
        ]);
        let text = doc.render();
        assert_eq!(text, r#"{"type":"infer","seed":61455,"docs":[[0,2,2],[]]}"#);
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"a": 3, "b": "x", "c": [1, 2.5], "d": null}"#).unwrap();
        assert_eq!(v.get("a").and_then(Json::as_u64), Some(3));
        assert_eq!(v.get("b").and_then(Json::as_str), Some("x"));
        assert_eq!(v.get("c").and_then(Json::as_arr).map(|a| a.len()), Some(2));
        assert_eq!(v.get("c").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("c").unwrap().as_arr().unwrap()[1].as_u64(), None);
        assert!(v.get("d").is_some());
        assert!(v.get("e").is_none());
        // Negative and fractional numbers are not u64.
        assert_eq!(Json::parse("-4").unwrap().as_u64(), None);
    }

    #[test]
    fn strings_escape_and_unescape() {
        let s = Json::Str("line\nquote\"back\\slash\ttab".into());
        let text = s.render();
        assert_eq!(Json::parse(&text).unwrap(), s);
        assert_eq!(Json::parse(r#""\u0041\u00e9""#).unwrap(), Json::Str("Aé".into()));
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("\"ctrl\u{1}\"").is_err());
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "[1 2]", "tru", "nul", "01a", "{} garbage",
            "\"\\q\"", "1e999",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
        // Depth bomb.
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn numbers_render_integers_exactly() {
        assert_eq!(Json::num(0.0).render(), "0");
        assert_eq!(Json::num(-7.0).render(), "-7");
        assert_eq!(Json::num(2.5).render(), "2.5");
        let big = 9_007_199_254_740_992.0; // 2^53 round-trips
        assert_eq!(Json::parse(&Json::num(big).render()).unwrap().as_u64(), Some(1 << 53));
    }
}
