//! Serving observability: latency histograms, throughput counters, and
//! the `stats` snapshot the TCP front end reports.
//!
//! Latencies land in the shared [`crate::obs::Log2Histogram`]
//! (re-exported here as [`LatencyHistogram`]), so recording is O(1),
//! lock-held time is tiny, and percentiles are exact to a factor of two
//! — plenty for the starved-vs-full cache comparisons of bench
//! `serve_latency`, which differ by orders of magnitude. The `metrics`
//! verb renders these same counters as Prometheus text via
//! [`StatsSnapshot::to_prometheus`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::obs::{self, names};

use super::json::Json;
use super::model::{CacheStats, DiskStats};

/// The serving tier's latency histogram — the lifted
/// [`crate::obs::Log2Histogram`], shared with the disk-recall timer and
/// the distributed master's round-wait meter.
pub use crate::obs::Log2Histogram as LatencyHistogram;

/// Shared serving counters; one instance per server/harness, updated by
/// the batch executor and read (lock-briefly) by `stats` requests.
pub struct ServeMetrics {
    start: Instant,
    hist: Mutex<LatencyHistogram>,
    requests: AtomicU64,
    docs: AtomicU64,
    tokens: AtomicU64,
    batches: AtomicU64,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    /// Fresh counters; throughput is measured from this instant.
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            start: Instant::now(),
            hist: Mutex::new(LatencyHistogram::new()),
            requests: AtomicU64::new(0),
            docs: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            batches: AtomicU64::new(0),
        }
    }

    /// Record one completed request: queue-to-reply latency plus its
    /// document/token volume.
    pub fn record_request(&self, latency_micros: u64, docs: u64, tokens: u64) {
        self.hist.lock().expect("metrics lock poisoned").record(latency_micros);
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.docs.fetch_add(docs, Ordering::Relaxed);
        self.tokens.fetch_add(tokens, Ordering::Relaxed);
    }

    /// Record one executed micro-batch.
    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    /// A copy of the request-latency histogram (for Prometheus
    /// exposition, which renders the full distribution rather than the
    /// snapshot's three percentiles).
    pub fn latency_histogram(&self) -> LatencyHistogram {
        self.hist.lock().expect("metrics lock poisoned").clone()
    }

    /// A consistent-enough snapshot for reporting (counters are relaxed;
    /// the histogram is copied under its lock).
    pub fn snapshot(&self, cache: CacheStats, disk: DiskStats) -> StatsSnapshot {
        let hist = self.hist.lock().expect("metrics lock poisoned").clone();
        let elapsed = self.start.elapsed().as_secs_f64().max(1e-9);
        let docs = self.docs.load(Ordering::Relaxed);
        StatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            docs,
            tokens: self.tokens.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            elapsed_secs: elapsed,
            docs_per_sec: docs as f64 / elapsed,
            p50_ms: hist.percentile_ms(50.0),
            p95_ms: hist.percentile_ms(95.0),
            p99_ms: hist.percentile_ms(99.0),
            cache,
            disk,
        }
    }
}

/// What a `stats` request returns: request/volume counters, latency
/// percentiles, throughput, and the block cache's hit/byte accounting.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    /// Requests completed.
    pub requests: u64,
    /// Documents folded in.
    pub docs: u64,
    /// Tokens sampled over.
    pub tokens: u64,
    /// Micro-batches executed.
    pub batches: u64,
    /// Seconds since the metrics were created.
    pub elapsed_secs: f64,
    /// Documents per wall-clock second since startup.
    pub docs_per_sec: f64,
    /// Median request latency (ms, log₂-bucket upper bound).
    pub p50_ms: f64,
    /// 95th-percentile request latency (ms).
    pub p95_ms: f64,
    /// 99th-percentile request latency (ms).
    pub p99_ms: f64,
    /// Block-cache counters at snapshot time.
    pub cache: CacheStats,
    /// Out-of-core disk-tier counters at snapshot time (all zeros when
    /// the backing store has no disk tier attached).
    pub disk: DiskStats,
}

impl StatsSnapshot {
    /// The snapshot as the wire-format `stats` response body.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("type".into(), Json::str("stats")),
            ("requests".into(), Json::num(self.requests as f64)),
            ("docs".into(), Json::num(self.docs as f64)),
            ("tokens".into(), Json::num(self.tokens as f64)),
            ("batches".into(), Json::num(self.batches as f64)),
            ("elapsed_secs".into(), Json::num(self.elapsed_secs)),
            ("docs_per_sec".into(), Json::num(self.docs_per_sec)),
            ("p50_ms".into(), Json::num(self.p50_ms)),
            ("p95_ms".into(), Json::num(self.p95_ms)),
            ("p99_ms".into(), Json::num(self.p99_ms)),
            ("cache_hits".into(), Json::num(self.cache.hits as f64)),
            ("cache_misses".into(), Json::num(self.cache.misses as f64)),
            ("cache_bypasses".into(), Json::num(self.cache.bypasses as f64)),
            ("cache_evictions".into(), Json::num(self.cache.evictions as f64)),
            ("cache_hit_rate".into(), Json::num(self.cache.hit_rate())),
            ("cache_resident_blocks".into(), Json::num(self.cache.resident_blocks as f64)),
            ("cache_resident_bytes".into(), Json::num(self.cache.resident_bytes as f64)),
            ("cache_peak_bytes".into(), Json::num(self.cache.peak_bytes as f64)),
            ("cache_budget_bytes".into(), Json::num(self.cache.budget_bytes as f64)),
            ("disk_attached".into(), Json::Bool(self.disk.attached)),
            ("disk_recalls".into(), Json::num(self.disk.recalls as f64)),
            ("disk_recall_bytes".into(), Json::num(self.disk.recall_bytes as f64)),
            ("disk_spill_bytes".into(), Json::num(self.disk.spill_bytes as f64)),
            ("disk_recall_p99_ms".into(), Json::num(self.disk.recall_p99_ms)),
        ])
    }

    /// Export the snapshot into an [`obs::Registry`] under the stable
    /// [`obs::names`] vocabulary — the single place serve counters map
    /// to metric names, shared by the server's `metrics` verb and
    /// [`super::harness::Harness::prometheus`].
    pub fn export(&self, reg: &obs::Registry) {
        reg.set_counter(names::SERVE_REQUESTS, "Requests completed.", &[], self.requests);
        reg.set_counter(names::SERVE_DOCS, "Documents folded in.", &[], self.docs);
        reg.set_counter(names::SERVE_TOKENS, "Tokens sampled over.", &[], self.tokens);
        reg.set_counter(names::SERVE_BATCHES, "Micro-batches executed.", &[], self.batches);
        reg.set_gauge(
            names::SERVE_DOCS_PER_SEC,
            "Documents per wall-clock second since startup.",
            &[],
            self.docs_per_sec,
        );
        let c = &self.cache;
        reg.set_counter(names::SERVE_CACHE_HITS, "Serve cache hits.", &[], c.hits);
        reg.set_counter(names::SERVE_CACHE_MISSES, "Serve cache misses.", &[], c.misses);
        reg.set_counter(
            names::SERVE_CACHE_BYPASSES,
            "Oversized blocks served without caching.",
            &[],
            c.bypasses,
        );
        reg.set_counter(names::SERVE_CACHE_EVICTIONS, "Serve cache evictions.", &[], c.evictions);
        reg.set_gauge(
            names::SERVE_CACHE_BLOCKS,
            "Blocks resident in the serve cache.",
            &[],
            c.resident_blocks as f64,
        );
        reg.set_gauge(
            names::SERVE_CACHE_BYTES,
            "Bytes resident in the serve cache.",
            &[],
            c.resident_bytes as f64,
        );
        let d = &self.disk;
        reg.set_counter(names::SERVE_DISK_RECALLS, "Disk-tier block recalls.", &[], d.recalls);
        reg.set_counter(
            names::SERVE_DISK_RECALL_BYTES,
            "Bytes recalled from the disk tier.",
            &[],
            d.recall_bytes,
        );
    }

    /// The snapshot rendered as Prometheus text exposition format,
    /// including the request-latency and disk-recall-latency
    /// distributions (both log₂ histograms, rendered in seconds).
    pub fn to_prometheus(
        &self,
        latency: &LatencyHistogram,
        recall: &LatencyHistogram,
    ) -> String {
        let reg = obs::Registry::new();
        self.export(&reg);
        reg.set_histogram(
            names::SERVE_LATENCY,
            "Request queue-to-reply latency (seconds).",
            &[],
            latency,
        );
        reg.set_histogram(
            names::SERVE_DISK_RECALL_LATENCY,
            "Cache-miss recall latency from the disk tier (seconds).",
            &[],
            recall,
        );
        reg.render_prometheus()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_bracket_samples() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.percentile_ms(99.0), 0.0);
        // 90 fast samples (~100 µs), 10 slow (~50 ms).
        for _ in 0..90 {
            h.record(100);
        }
        for _ in 0..10 {
            h.record(50_000);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile_ms(50.0);
        let p99 = h.percentile_ms(99.0);
        assert!(p50 >= 0.1 && p50 <= 0.3, "p50={p50}");
        assert!(p99 >= 50.0 && p99 <= 70.0, "p99={p99}");
        assert!(h.percentile_ms(89.0) <= p99);
        // Zero-latency samples land in the first bucket, not a panic.
        h.record(0);
        assert!(h.percentile_ms(1.0) > 0.0);
    }

    #[test]
    fn snapshot_counts_and_renders() {
        let m = ServeMetrics::new();
        m.record_batch();
        m.record_request(1_000, 4, 120);
        m.record_request(2_000, 1, 30);
        let disk = DiskStats {
            attached: true,
            recalls: 3,
            recall_bytes: 700,
            spill_bytes: 900,
            recall_p99_ms: 0.5,
        };
        let snap = m.snapshot(CacheStats::default(), disk);
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.docs, 5);
        assert_eq!(snap.tokens, 150);
        assert_eq!(snap.batches, 1);
        assert!(snap.docs_per_sec > 0.0);
        assert!(snap.p99_ms >= snap.p50_ms);
        let j = snap.to_json();
        assert_eq!(j.get("type").and_then(Json::as_str), Some("stats"));
        assert_eq!(j.get("docs").and_then(Json::as_u64), Some(5));
        assert_eq!(j.get("disk_attached"), Some(&Json::Bool(true)));
        assert_eq!(j.get("disk_recalls").and_then(Json::as_u64), Some(3));
        assert_eq!(j.get("disk_spill_bytes").and_then(Json::as_u64), Some(900));
        // Round-trips through the wire format.
        assert_eq!(Json::parse(&j.render()).unwrap(), j);
    }
}
