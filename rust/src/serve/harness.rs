//! In-process serving harness: the full serving stack — sharded model,
//! micro-batcher, executor thread, metrics — with **no sockets**.
//!
//! Tests drive it to assert the serving tier's determinism contract:
//! a request served through batching and paging returns `DocTopics`
//! bitwise identical to `TopicModel::infer_with` over the same documents
//! and seed, at every cache budget and batch size
//! (`tests/serve_determinism.rs`). The TCP front end
//! ([`super::server::Server`]) runs this same harness behind a socket,
//! so what the harness proves, the server inherits.

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::engine::{BowDoc, DocTopics};

use super::batcher::{run_executor, BatchOpts, Batcher, InferRequest};
use super::metrics::{ServeMetrics, StatsSnapshot};
use super::model::ShardedTopicModel;

/// A live in-process serving stack. Dropping it closes the queue and
/// joins the executor.
pub struct Harness {
    model: Arc<ShardedTopicModel>,
    batcher: Arc<Batcher>,
    metrics: Arc<ServeMetrics>,
    executor: Option<JoinHandle<()>>,
}

impl Harness {
    /// Spin up the stack over a model, spawning the batch-executor
    /// thread.
    pub fn new(model: ShardedTopicModel, opts: BatchOpts) -> Harness {
        Self::over(Arc::new(model), opts)
    }

    /// [`Harness::new`] over an already-shared model.
    pub fn over(model: Arc<ShardedTopicModel>, opts: BatchOpts) -> Harness {
        let batcher = Arc::new(Batcher::new(opts));
        let metrics = Arc::new(ServeMetrics::new());
        let executor = {
            let (model, batcher, metrics) =
                (Arc::clone(&model), Arc::clone(&batcher), Arc::clone(&metrics));
            std::thread::spawn(move || run_executor(&model, &batcher, &metrics))
        };
        Harness { model, batcher, metrics, executor: Some(executor) }
    }

    /// The model being served.
    pub fn model(&self) -> &ShardedTopicModel {
        &self.model
    }

    /// Shared handles for a front end layered on this harness.
    pub(crate) fn shared(
        &self,
    ) -> (Arc<ShardedTopicModel>, Arc<Batcher>, Arc<ServeMetrics>) {
        (Arc::clone(&self.model), Arc::clone(&self.batcher), Arc::clone(&self.metrics))
    }

    /// Enqueue a request; the reply arrives asynchronously on the
    /// returned channel (tests submit many before receiving any, to
    /// exercise real batching).
    pub fn submit(&self, req: InferRequest) -> Receiver<Result<DocTopics>> {
        self.batcher.submit(req)
    }

    /// Submit one request and wait for its reply.
    pub fn infer(&self, docs: Vec<BowDoc>, seed: u64, iterations: usize) -> Result<DocTopics> {
        self.submit(InferRequest { docs, seed, iterations })
            .recv()
            .map_err(|_| anyhow!("serving executor hung up"))?
    }

    /// Current serving statistics (what the TCP `stats` request returns).
    pub fn stats(&self) -> StatsSnapshot {
        self.metrics.snapshot(self.model.cache_stats(), self.model.disk_stats())
    }

    /// The serving counters rendered as Prometheus text exposition —
    /// the body of the TCP `metrics` response, available in-process so
    /// tests and embedders need no socket to scrape.
    pub fn prometheus(&self) -> String {
        self.stats()
            .to_prometheus(&self.metrics.latency_histogram(), &self.model.recall_histogram())
    }

    /// Close the queue, drain outstanding work, and join the executor.
    /// (Dropping the harness does the same.)
    pub fn shutdown(self) {}
}

impl Drop for Harness {
    fn drop(&mut self) {
        self.batcher.close();
        if let Some(handle) = self.executor.take() {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{TopicCounts, WordTopicTable};
    use crate::sampler::Params;
    use crate::util::rng::Pcg64;

    fn model() -> ShardedTopicModel {
        let (v, k) = (80, 8);
        let mut rng = Pcg64::new(21);
        let mut wt = WordTopicTable::zeros(v, k);
        let mut ck = TopicCounts::zeros(k);
        for w in 0..v {
            for _ in 0..rng.next_below(5) {
                let t = rng.next_below(k as u64) as u32;
                wt.row_mut(w).inc(t);
                ck.inc(t as usize);
            }
        }
        let params = Params::new(k, v, 0.1, 0.01);
        ShardedTopicModel::from_table(&wt, ck, params, 8, 0.0).unwrap()
    }

    #[test]
    fn serves_requests_and_reports_stats() {
        let h = Harness::new(model(), BatchOpts::default());
        let folded = h.infer(vec![BowDoc::new(vec![1, 2, 3, 3])], 7, 5).unwrap();
        assert_eq!(folded.len(), 1);
        assert_eq!(folded.counts(0).total(), 4);
        // Async pile-up: all replies arrive, in whatever batching.
        let rxs: Vec<_> = (0..10u64)
            .map(|i| {
                h.submit(InferRequest {
                    docs: vec![BowDoc::new(vec![i as u32, (i + 1) as u32])],
                    seed: i,
                    iterations: 3,
                })
            })
            .collect();
        for rx in rxs {
            let reply = rx.recv().expect("executor alive").expect("infer ok");
            assert_eq!(reply.len(), 1);
        }
        let stats = h.stats();
        assert_eq!(stats.requests, 11);
        assert_eq!(stats.docs, 11);
        assert!(stats.batches >= 1);
        assert!(stats.p99_ms > 0.0);
        h.shutdown();
    }

    #[test]
    fn prometheus_rendering_round_trips() {
        let h = Harness::new(model(), BatchOpts::default());
        h.infer(vec![BowDoc::new(vec![1, 2, 3])], 5, 3).unwrap();
        let text = h.prometheus();
        let summary = crate::obs::prometheus::parse(&text).expect("exposition parses");
        assert!(summary.families >= 10, "{text}");
        assert!(text.contains(crate::obs::names::SERVE_REQUESTS), "{text}");
        assert!(
            text.contains(&format!("{}_bucket", crate::obs::names::SERVE_LATENCY)),
            "{text}"
        );
        h.shutdown();
    }

    #[test]
    fn request_errors_come_back_as_replies() {
        let h = Harness::new(model(), BatchOpts::default());
        let err = h
            .infer(vec![BowDoc::new(vec![9_999])], 1, 5)
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("vocabulary"), "{err}");
        let err =
            h.infer(vec![BowDoc::new(vec![1])], 1, 0).map(|_| ()).unwrap_err().to_string();
        assert!(err.contains("iterations"), "{err}");
        // The executor survives bad requests.
        assert!(h.infer(vec![BowDoc::new(vec![1])], 1, 2).is_ok());
    }
}
