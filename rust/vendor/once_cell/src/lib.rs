//! Vendored offline shim of `once_cell`: just `sync::Lazy`, built on
//! `std::sync::OnceLock` (no unsafe).

pub mod sync {
    use std::ops::Deref;
    use std::sync::{Mutex, OnceLock};

    /// A value initialized on first access, safe for `static`s.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: Mutex<Option<F>>,
    }

    impl<T, F: FnOnce() -> T> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy { cell: OnceLock::new(), init: Mutex::new(Some(init)) }
        }

        pub fn force(this: &Lazy<T, F>) -> &T {
            this.cell.get_or_init(|| {
                let f = this
                    .init
                    .lock()
                    .expect("Lazy init lock poisoned")
                    .take()
                    .expect("Lazy initializer already taken");
                f()
            })
        }
    }

    impl<T, F: FnOnce() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::Lazy;

    static GLOBAL: Lazy<Vec<u32>> = Lazy::new(|| vec![1, 2, 3]);

    #[test]
    fn static_lazy_initializes_once() {
        assert_eq!(GLOBAL.len(), 3);
        assert_eq!(GLOBAL[0], 1);
    }

    #[test]
    fn local_lazy() {
        let l: Lazy<u32, _> = Lazy::new(|| 40 + 2);
        assert_eq!(*l, 42);
        assert_eq!(*l, 42);
    }
}
