//! Vendored offline shim of `libc`: exactly the `clock_gettime` surface
//! `mplda::util::cputime` uses (Linux).

#![allow(non_camel_case_types)]

pub type c_int = i32;
pub type c_long = i64;
pub type time_t = i64;
pub type clockid_t = c_int;

/// `CLOCK_THREAD_CPUTIME_ID` — the value is OS-specific; this shim only
/// supports the platforms it has been checked on (the real crate covers
/// the rest — swap it in if this ever needs to build elsewhere).
#[cfg(target_os = "linux")]
pub const CLOCK_THREAD_CPUTIME_ID: clockid_t = 3;
#[cfg(target_os = "macos")]
pub const CLOCK_THREAD_CPUTIME_ID: clockid_t = 16;
#[cfg(not(any(target_os = "linux", target_os = "macos")))]
compile_error!(
    "vendored libc shim: CLOCK_THREAD_CPUTIME_ID unknown for this target OS"
);

#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct timespec {
    pub tv_sec: time_t,
    pub tv_nsec: c_long,
}

extern "C" {
    pub fn clock_gettime(clk_id: clockid_t, tp: *mut timespec) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_clock_readable() {
        let mut ts = timespec { tv_sec: 0, tv_nsec: 0 };
        let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
        assert_eq!(rc, 0);
        assert!(ts.tv_sec >= 0 && ts.tv_nsec >= 0);
    }
}
