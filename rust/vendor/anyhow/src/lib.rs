//! Vendored offline shim of the `anyhow` API surface mplda uses.
//!
//! The build runs with no network access, so instead of the crates.io
//! `anyhow` this workspace vendors the subset the codebase touches:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros. Error chains are flattened to
//! `"context: cause"` strings at attachment time — the repo only ever
//! renders errors via `Display`/`Debug`, never downcasts.

use std::fmt;

/// A flattened error: the full context chain rendered into one message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a pre-rendered message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string() }
    }

    /// Prepend a context layer (`"context: cause"`).
    pub fn context(self, context: impl fmt::Display) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?` conversion from any std error. `Error` itself deliberately does NOT
// implement `std::error::Error`, exactly like the real crate, so this
// blanket impl cannot collide with `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>`: `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a displayable value, or a format
/// string with arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "Condition failed: `",
                ::std::stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path/9f3a")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chains_render_outermost_first() {
        let e: Result<()> = Err(Error::msg("root"));
        let e = e.context("layer").unwrap_err();
        assert_eq!(e.to_string(), "layer: root");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing thing").unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("too big: 12"));
        assert!(f(5).unwrap_err().to_string().contains("five"));
        let s = String::from("wrapped");
        assert_eq!(anyhow!(s).to_string(), "wrapped");
    }

    #[test]
    fn ensure_without_message() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("Condition failed"));
    }
}
