//! Vendored offline shim of the `log` facade: leveled macros, the [`Log`]
//! trait, and the global logger/level registry. API-compatible with the
//! subset `mplda::util::logger` and the experiment harnesses use.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of one log record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        // Respect width/alignment ({:5} in the logger's format string).
        f.pad(s)
    }
}

/// Maximum-verbosity filter installed via [`set_max_level`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record (level + target module path).
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging sink. Mirrors the real facade: loggers are shared across
/// threads, hence the `Send + Sync` supertraits.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // Off until installed

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

/// Install the global logger (first call wins).
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level; records above it are skipped entirely.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::SeqCst);
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::SeqCst) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not part of the public API contract.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if (level as usize) > MAX_LEVEL.load(Ordering::SeqCst) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

#[doc(hidden)]
#[macro_export]
macro_rules! __log_at {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, ::std::module_path!(), ::std::format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::__log_at!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::__log_at!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::__log_at!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::__log_at!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::__log_at!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;
    impl Log for Counter {
        fn enabled(&self, _: &Metadata<'_>) -> bool {
            true
        }
        fn log(&self, record: &Record<'_>) {
            HITS.fetch_add(1, Ordering::SeqCst);
            assert!(!format!("{}", record.args()).is_empty());
            assert!(record.target().contains("log"));
        }
        fn flush(&self) {}
    }

    #[test]
    fn filtering_and_dispatch() {
        let _ = set_boxed_logger(Box::new(Counter));
        set_max_level(LevelFilter::Info);
        info!("hello {}", 1);
        debug!("filtered {}", 2);
        assert_eq!(HITS.load(Ordering::SeqCst), 1);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
    }
}
